"""Backend-dispatch layer: jit'd wrappers around the Pallas kernels.

These are the public entry points the core plans and the FFT service route
through (DESIGN.md §6).  They accept/return either natural complex arrays
or planar f32 planes, handle the planar split, and pick factorizations and
block sizes.

Execution-mode policy (the reason the kernel path is the *default* engine
and not a TPU-only demo).  Every kernel's math lives in a pure
``*_body`` function shared by two callers:

* **pallas** -- ``pl.pallas_call`` with VMEM-sized blocks; compiled on
  TPU, ``interpret=True`` elsewhere.  The parity tests pin
  ``interpret=True`` so every body is exercised through the real Pallas
  machinery on CPU in every PR.
* **direct** -- the body evaluated on the full batch as straight XLA.
  This is the off-TPU default (``interpret=None``): the interpret-mode
  grid emulation pays per-call buffer-copy overhead (~ms per bucket at
  service sizes) that would hand the hot path back to the jnp oracle,
  while the direct body is the identical math (bit-identical results)
  at zero overhead.

``interpret=None`` therefore means "compiled pallas on TPU, direct body
elsewhere"; an explicit ``interpret=True/False`` forces the Pallas call.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune, ref
from repro.kernels.cmatmul import (
    bcmatmul,
    bcmatmul_body,
    cmatmul,
    cmatmul_body,
)
from repro.kernels.coded_pipeline import (
    bucket_body,
    bucket_body_fftworker,
    bucket_body_masked,
    coded_fft_bucket,
    coded_fft_bucket_masked,
    coded_fft_bucket_streaming,
    coded_fft_bucket_streaming_masked,
    coded_irfft_bucket,
    coded_irfft_bucket_masked,
    coded_rfft_bucket,
    coded_rfft_bucket_masked,
    half_postdecode_body,
    ir_message_body,
    ir_unpack_body,
    irbucket_body,
    irbucket_body_fftworker,
    irbucket_body_masked,
    lagrange_planes_body,
    pack_real_planes,
    rbucket_body,
    rbucket_body_fftworker,
    rbucket_body_masked,
    subsets_from_masks_body,
)
from repro.kernels.fourstep_fft import (
    _parse_stage_planes,
    encode_fourstep_body,
    encode_fourstep_fused,
    fourstep_body,
    fourstep_fused,
    fourstep_stage1,
    fourstep_stage2,
    fourstep_streaming,
    multistep_body,
    multistep_fused,
    stage1_body,
    stage2_body,
)
from repro.kernels.recombine import (
    recombine_batched_body,
    recombine_body,
    recombine_twiddle_dft,
    recombine_twiddle_dft_batched,
)

__all__ = [
    "default_interpret",
    "kernel_backend_supported",
    "split_factor",
    "fft_fourstep",
    "fourstep_planar",
    "encode_worker",
    "decode_apply",
    "recombine_planar",
    "mask_subsets",
    "lagrange_compact_planes",
    "lagrange_scatter_planes",
    "coded_bucket",
    "coded_bucket_direct",
    "coded_bucket_fusable",
    "coded_bucket_streamable",
    "coded_bucket_masked",
    "coded_rbucket",
    "coded_rbucket_direct",
    "coded_rbucket_fusable",
    "coded_rbucket_masked",
    "coded_irbucket",
    "coded_irbucket_direct",
    "coded_irbucket_fusable",
    "coded_irbucket_masked",
    "pack_real_planes",
    "rfft_postdecode_planar",
    "irfft_message_planar",
    "irfft_unpack_planar",
    "mds_apply",
    "recombine_fused",
    "make_kernel_worker_fn",
    "make_kernel_fftn_fn",
]

# VMEM budget heuristic (TPU, compiled): fused kernel keeps ~4 (A,B) planes
# + 2 (A,A) + 2 (B,B) + 2 (A,B) twiddle planes resident; cap the fused path
# at the size where that stays under ~12 MB of the 16 MB VMEM.
_FUSED_MAX_ELEMS = 512 * 512
# Interpret-mode (host) block budget: collapse the batch into one grid step
# whenever a block stays under ~32 MB/plane -- the collapsed call traces the
# kernel body once and lowers to plain fused XLA matmuls.
_INTERPRET_BLOCK_ELEMS = 1 << 23

# bf16-plane mode: DFT/twiddle constants in bfloat16 with f32 payload and
# f32 accumulation (mixed-dtype dots promote).  Halves the constant-plane
# VMEM footprint; the relative error budget the property suite holds the
# mode to -- a plan size that exceeds it gets bf16 auto-disabled per (s, m)
# by the service warmup probe.
BF16_RTOL = 2e-2


def _plane_dtype(precision: str):
    if precision == "bf16":
        return jnp.bfloat16  # ml_dtypes.bfloat16: numpy-compatible
    if precision in (None, "f32", "float32"):
        return np.float32
    raise ValueError(f"unknown plane precision {precision!r}")


def default_interpret() -> bool:
    """Pallas interpret mode everywhere except real TPU backends."""
    return jax.default_backend() != "tpu"


def _mode(interpret: bool | None) -> str:
    """Resolve the execution mode: ``"compiled"`` | ``"interpret"`` |
    ``"direct"`` (see module docstring)."""
    if interpret is None:
        return "direct" if default_interpret() else "compiled"
    return "interpret" if interpret else "compiled"


def kernel_backend_supported(dtype) -> bool:
    """The planar kernels compute in f32 planes: complex64 plans only.

    complex128 plans (the numerics/reference tier) resolve to the jnp
    backend -- the dispatch rule in DESIGN.md §6.
    """
    return jnp.dtype(dtype) == jnp.dtype(jnp.complex64)


def split_factor(n: int) -> tuple[int, int]:
    """Factor ``n = a * b`` with a, b as close as possible (a <= b).

    MXU-friendliness: prefers multiples of 128 when available; for powers of
    two this returns (2^floor(k/2), 2^ceil(k/2)).  Primes fall back to
    (1, n): stage 1 degenerates to the identity and stage 2 is one dense
    DFT matmul.
    """
    a = int(math.isqrt(n))
    while a > 1 and n % a != 0:
        a -= 1
    return a, n // a


def _block_q(batch: int, per_elem: int, interpret: bool) -> int:
    """Batch elements per grid step under the active memory budget."""
    budget = _INTERPRET_BLOCK_ELEMS if interpret else _FUSED_MAX_ELEMS
    return max(1, min(batch, budget // max(per_elem, 1)))


def _block_l(total: int, rows: int, interpret: bool) -> int:
    """Payload columns per grid step for the streaming matmul kernels."""
    if interpret:
        return max(1, min(total, _INTERPRET_BLOCK_ELEMS // max(rows, 1)))
    return min(total, 512)


def _tuned_block_q(kind: str, q: int, per_elem: int, mode: str,
                   **params) -> int:
    """Measured batch-block size, falling back to the VMEM heuristic.

    Every dispatcher routes through this: a ``lookup`` into the autotune
    table (populated by ``FFTService.warmup()`` / the bench harness, keyed
    per backend+mode+shape) is a pure dict read, so dispatch stays
    trace-time cheap; a miss degrades to the old :func:`_block_q` rule.
    """
    ent = autotune.lookup(kind, mode=mode, **params)
    if ent and "block_q" in ent:
        return max(1, min(q, int(ent["block_q"])))
    return _block_q(q, per_elem, mode == "interpret")


# Twiddle/DFT planes are computed with NUMPY and memoized: called inside a
# jit trace they embed as concrete constants, so the cos/sin construction
# is paid once per (shape) at trace time -- XLA:CPU does NOT constant-fold
# a traced transcendental table, and rebuilding the (m, L) recombine planes
# per bucket call used to cost about as much as the decode matmul itself.
@functools.lru_cache(maxsize=None)
def _dft_planes(n: int, dtype=np.float32, sign: float = -1.0):
    # sign=-1 forward DFT; sign=+1 the adjoint (c2r fold, DESIGN.md §7)
    jk = np.outer(np.arange(n), np.arange(n))
    ang = sign * 2.0 * np.pi * (jk % n) / n
    return np.cos(ang).astype(dtype), np.sin(ang).astype(dtype)


@functools.lru_cache(maxsize=None)
def _twiddle_planes(a: int, b: int, dtype=np.float32):
    # W[c, b] = omega_{a*b}^{c*b}
    cb = np.outer(np.arange(a), np.arange(b))
    ang = -2.0 * np.pi * (cb % (a * b)) / (a * b)
    return np.cos(ang).astype(dtype), np.sin(ang).astype(dtype)


@functools.lru_cache(maxsize=None)
def _recombine_planes(s: int, m: int, dtype=np.float32, sign: float = -1.0):
    # recombine twiddle W[k, i] = omega_s^{ik} plus the length-m DFT planes;
    # sign=+1 gives the adjoint pair (conjugate twiddle, F+) the c2r
    # message stage uses
    ki = np.outer(np.arange(m), np.arange(s // m))
    ang = sign * 2.0 * np.pi * (ki % s) / s
    return (np.cos(ang).astype(dtype), np.sin(ang).astype(dtype),
            *_dft_planes(m, dtype, sign))


@functools.lru_cache(maxsize=None)
def _half_dft_planes(m: int, dtype=np.float32):
    # the m//2 + 1 non-redundant butterfly rows of the length-m DFT
    jk = np.outer(np.arange(m // 2 + 1), np.arange(m))
    ang = -2.0 * np.pi * (jk % m) / m
    return np.cos(ang).astype(dtype), np.sin(ang).astype(dtype)


@functools.lru_cache(maxsize=None)
def _split_planes(ell: int, dtype=np.float32, sign: float = -1.0):
    # r2c split twiddle exp(sign*2j*pi*p/L), p <= L/2, as (1, L/2+1);
    # sign=+1 is the c2r pack twiddle (the inverse butterfly's)
    ang = sign * 2.0 * np.pi * np.arange(ell // 2 + 1) / ell
    return (np.cos(ang)[None, :].astype(dtype),
            np.sin(ang)[None, :].astype(dtype))


@functools.lru_cache(maxsize=None)
def _recombine_planes_scrambled(s: int, m: int, a: int, b: int,
                                dtype=np.float32):
    """Recombine planes with the twiddle permuted to the four-step payload
    order ``l' = c*B + d`` for natural ``l = c + d*A`` -- the bucket kernel
    carries that order through decode and unscrambles only at the output
    (kernels/coded_pipeline.py)."""
    twr, twi, fr, fi = _recombine_planes(s, m, dtype)
    perm = lambda t: np.ascontiguousarray(
        t.reshape(m, b, a).transpose(0, 2, 1).reshape(m, a * b))
    return perm(twr), perm(twi), fr, fi


@functools.lru_cache(maxsize=None)
def _multistep_planes(factors: tuple, dtype=np.float32):
    """Flat plane list for the mixed-radix multistep kernel.

    Per stage: the (f, f) DFT planes, then (for every stage but the last)
    the (f, rest) inter-stage twiddle where ``rest`` is the product of the
    remaining factors -- exactly the ordering
    ``fourstep_fft._parse_stage_planes`` regroups.
    """
    rest = 1
    for f in factors:
        rest *= f
    planes: list = []
    for idx, f in enumerate(factors):
        rest //= f
        planes.extend(_dft_planes(f, dtype))
        if idx < len(factors) - 1:
            planes.extend(_twiddle_planes(f, rest, dtype))
    return tuple(planes)


# ---------------------------------------------------------------- four-step
def fourstep_planar(xr: jax.Array, xi: jax.Array, *,
                    interpret: bool | None = None,
                    fused: bool | None = None,
                    variant: str | None = None,
                    factors=None,
                    precision: str = "f32"):
    """Batched planar FFT along the last axis via the four-step kernels.

    ``xr, xi``: (batch, L) f32 planes.  Returns natural-order (batch, L)
    planes of ``fft(x, axis=-1)``.

    ``variant`` selects the execution plan explicitly: ``"fused"`` (one
    launch; mixed-radix multistep when ``factors`` has > 2 entries),
    ``"two_pass"`` (stage1/stage2 kernels), ``"streaming"`` (double-
    buffered DMA grid, natural-order output), or ``"xla"`` (platform FFT).
    ``variant=None`` consults the autotune table for this (L, mode) and
    falls back to the VMEM heuristic on a miss: fused when the (A, B)
    matrix fits the budget, else two-pass; degenerate factorizations
    (prime or near-prime L, where the dense (B, B) DFT factor would dwarf
    an FFT's flops AND its plane would not fit VMEM) take the platform
    FFT.  The legacy ``fused`` bool maps onto fused/two_pass.

    ``precision="bf16"`` casts the DFT/twiddle planes to bfloat16 while the
    matmuls still accumulate in f32 (``preferred_element_type``); gate on
    :data:`BF16_RTOL` -- see ``FFTService.warmup``'s per-shape probe.
    """
    mode = _mode(interpret)
    batch, ell = xr.shape
    a, b = split_factor(ell)
    if variant is None and fused is not None:
        variant = "fused" if fused else "two_pass"
    if variant is None:
        ent = autotune.lookup("fourstep", L=ell, mode=mode)
        if ent:
            variant = ent.get("variant")
            if factors is None and ent.get("factors"):
                factors = tuple(ent["factors"])
    if variant is None:
        if b * b > _FUSED_MAX_ELEMS:
            variant = "xla"
        elif a * b <= _FUSED_MAX_ELEMS:
            variant = "fused"
        else:
            variant = "two_pass"
    if variant != "xla" and b * b > _FUSED_MAX_ELEMS and not (
            variant == "fused" and factors is not None and len(factors) > 2):
        # degenerate split: the dense (B, B) plane cannot fit -- the only
        # honest kernels are a multistep plan or the platform FFT
        variant = "xla"
    if variant == "xla":
        z = jnp.fft.fft(xr + 1j * xi, axis=-1)
        return jnp.real(z).astype(xr.dtype), jnp.imag(z).astype(xr.dtype)
    dt = _plane_dtype(precision)
    itp = mode == "interpret"
    if variant == "fused" and factors is not None and len(factors) > 2:
        factors = tuple(int(f) for f in factors)
        planes = _multistep_planes(factors, dt)
        if mode == "direct":
            outr, outi = multistep_body(
                xr, xi, _parse_stage_planes(factors, planes))
        else:
            bq = _tuned_block_q("fourstep", batch, ell, mode, L=ell)
            outr, outi = multistep_fused(
                xr, xi, planes, factors, block_q=bq, interpret=itp)
        # digit-reversed output X[c1 + f1*c2 + ...] -> reverse the axes
        k = len(factors)
        outr = outr.reshape(batch, *factors).transpose(
            (0,) + tuple(range(k, 0, -1))).reshape(batch, ell)
        outi = outi.reshape(batch, *factors).transpose(
            (0,) + tuple(range(k, 0, -1))).reshape(batch, ell)
        return outr, outi
    if factors is not None and len(factors) == 2:
        a, b = int(factors[0]), int(factors[1])
    far, fai = _dft_planes(a, dt)
    fbr, fbi = _dft_planes(b, dt)
    wr, wi = _twiddle_planes(a, b, dt)
    if variant == "streaming" and mode != "direct":
        ent = autotune.lookup("fourstep", L=ell, mode=mode) or {}
        outr, outi = fourstep_streaming(
            xr.reshape(batch, a, b), xi.reshape(batch, a, b),
            far, fai, wr, wi, fbr, fbi,
            block_q=int(ent.get("block_q", 1) or 1),
            block_a=int(ent.get("block_a", 256) or 256),
            block_b=int(ent.get("block_b", 256) or 256),
            interpret=itp)
        # natural-order (batch, B, A) output: flat X, no unscramble
        return outr.reshape(batch, ell), outi.reshape(batch, ell)
    if variant == "streaming":
        variant = "two_pass"  # direct mode has no DMA grid to stream
    xr = xr.reshape(batch, a, b)
    xi = xi.reshape(batch, a, b)
    if mode == "direct":
        if variant == "fused":
            outr, outi = fourstep_body(xr, xi, far, fai, wr, wi, fbr, fbi)
        else:
            t1r, t1i = stage1_body(xr, xi, far, fai, wr, wi)
            outr, outi = stage2_body(t1r, t1i, fbr, fbi)
    else:
        bq = _tuned_block_q("fourstep", batch, a * b, mode, L=ell)
        if variant == "fused":
            outr, outi = fourstep_fused(
                xr, xi, far, fai, wr, wi, fbr, fbi,
                block_q=bq, interpret=itp)
        else:
            t1r, t1i = fourstep_stage1(
                xr, xi, far, fai, wr, wi, block_q=bq, interpret=itp)
            outr, outi = fourstep_stage2(
                t1r, t1i, fbr, fbi, block_q=bq, interpret=itp)
    # out[c, d] holds X[c + d*A]  ->  transpose to (d, c) then flatten
    outr = jnp.swapaxes(outr, -1, -2).reshape(batch, ell)
    outi = jnp.swapaxes(outi, -1, -2).reshape(batch, ell)
    return outr, outi


@functools.partial(jax.jit, static_argnames=("interpret", "fused"))
def _fft_fourstep_impl(x, interpret, fused):
    xr, xi = ref.planar(x)
    outr, outi = fourstep_planar(xr, xi, interpret=interpret, fused=fused)
    return ref.unplanar(outr, outi)


def fft_fourstep(x: jax.Array, *, interpret: bool | None = None,
                 fused: bool | None = None) -> jax.Array:
    """Batched FFT along the last axis via the Pallas four-step kernel.

    ``x``: (..., L) complex; L is factored automatically.  Non-batched
    inputs are promoted.  Output matches ``jnp.fft.fft(x, axis=-1)`` up to
    f32 planar precision.
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    batch_shape = x.shape[:-1]
    ell = x.shape[-1]
    out = _fft_fourstep_impl(
        x.reshape(-1, ell), interpret, fused
    ).reshape(batch_shape + (ell,))
    return out[0] if squeeze else out


# ------------------------------------------------- fused encode + worker
def encode_worker(cr: jax.Array, ci: jax.Array,
                  gr: jax.Array, gi: jax.Array, *,
                  interpret: bool | None = None,
                  fused: bool | None = None):
    """Message planes -> coded worker spectra: ``B = fft(G @ c, axis=-1)``.

    ``cr, ci``: (q, m, L) planes of the message shards; ``gr, gi``: (n, m)
    generator planes.  Returns natural-order (q, n, L) planes.

    ``fused=None`` picks the single-kernel fused path (encode contraction
    in VMEM, m-shard DFTs -- an N/m flop saving over transforming coded
    shards) when the per-element footprint fits the VMEM budget, else the
    two-pass fallback: streamed cmatmul encode, then the four-step worker
    on the coded rows.
    """
    mode = _mode(interpret)
    q, m, ell = cr.shape
    n = gr.shape[0]
    a, b = split_factor(ell)
    if fused is None:
        # degenerate factorization (b*b over budget): two-pass, whose
        # four-step stage falls back to the platform FFT
        fused = ((m + n) * a * b <= 2 * _FUSED_MAX_ELEMS
                 and b * b <= _FUSED_MAX_ELEMS)
    if fused:
        planes = (*_dft_planes(a), *_twiddle_planes(a, b), *_dft_planes(b))
        if mode == "direct":
            br_, bi_ = encode_fourstep_body(
                cr.reshape(q, m, a, b), ci.reshape(q, m, a, b), gr, gi,
                *planes)
        else:
            itp = mode == "interpret"
            bq = _block_q(q, (m + n) * a * b, itp)
            br_, bi_ = encode_fourstep_fused(
                cr.reshape(q, m, a, b), ci.reshape(q, m, a, b), gr, gi,
                *planes, block_q=bq, interpret=itp)
        br_ = jnp.swapaxes(br_, -1, -2).reshape(q, n, ell)
        bi_ = jnp.swapaxes(bi_, -1, -2).reshape(q, n, ell)
        return br_, bi_
    # two-pass: encode via the streaming cmatmul (batch folded into the
    # payload columns -- G is shared), then the planar four-step worker
    tr = jnp.transpose(cr, (1, 0, 2)).reshape(m, q * ell)
    ti = jnp.transpose(ci, (1, 0, 2)).reshape(m, q * ell)
    if mode == "direct":
        er, ei = cmatmul_body(gr, gi, tr, ti)
    else:
        itp = mode == "interpret"
        bl = _block_l(q * ell, m + n, itp)
        er, ei = cmatmul(gr, gi, tr, ti, block_l=bl, interpret=itp)
    ar = jnp.transpose(er.reshape(n, q, ell), (1, 0, 2)).reshape(q * n, ell)
    ai = jnp.transpose(ei.reshape(n, q, ell), (1, 0, 2)).reshape(q * n, ell)
    br_, bi_ = fourstep_planar(ar, ai, interpret=interpret)
    return br_.reshape(q, n, ell), bi_.reshape(q, n, ell)


# ------------------------------------------------------------ decode apply
def decode_apply(dr: jax.Array, di: jax.Array,
                 br: jax.Array, bi: jax.Array, *,
                 interpret: bool | None = None):
    """Per-request decode matrices applied as one batched MXU matmul.

    ``dr, di``: (q, m, N) planes of scatter decode matrices (zero columns
    for stragglers -- DESIGN.md §6); ``br, bi``: (q, N, L) worker-result
    planes.  Returns (q, m, L) decoded sub-transform planes.
    """
    mode = _mode(interpret)
    if mode == "direct":
        return bcmatmul_body(dr, di, br, bi)
    itp = mode == "interpret"
    q, m, n = dr.shape
    ell = br.shape[-1]
    bq = _block_q(q, (m + n) * ell, itp)
    bl = _block_l(ell, m + n, itp)
    return bcmatmul(dr, di, br, bi, block_q=bq, block_l=bl, interpret=itp)


# --------------------------------------- device-resident decode matrices
def mask_subsets(masks: jax.Array, m: int) -> jax.Array:
    """First-``m`` responder indices per request, in-trace.

    ``masks``: bool ``(B, N)``.  Returns ``(B, m)`` int32 -- the traced
    twin of ``DecodeMatrixCache.subset_of`` / ``mds.first_available``
    (stable argsort keeps arrival order), kept inline so the kernel layer
    never imports upward into ``repro.core``.
    """
    order = jnp.argsort(jnp.logical_not(jnp.asarray(masks)),
                        axis=-1, stable=True)
    return order[..., :m].astype(jnp.int32)


def lagrange_compact_planes(subsets: jax.Array, n: int):
    """Per-request compact ``(B, m, m)`` inverse planes from subsets --
    the gathered-decode form of the direct (off-TPU) bucket executors,
    built in-trace with no host inversion (DESIGN.md §8)."""
    ivr, ivi, _, _ = lagrange_planes_body(subsets, n)
    return ivr, ivi


def lagrange_scatter_planes(subsets: jax.Array, n: int):
    """Per-request scatter ``(B, m, N)`` decode planes (zero straggler
    columns) from subsets -- the MXU form :func:`decode_apply` and the
    stage-path kernels contract against."""
    _, _, dr, di = lagrange_planes_body(subsets, n)
    return dr, di


# -------------------------------------------------------------- recombine
def recombine_planar(cr: jax.Array, ci: jax.Array, s: int, *,
                     interpret: bool | None = None):
    """Batched master recombination on planes: (q, m, s/m) -> (q, s)."""
    mode = _mode(interpret)
    q, m, ell = cr.shape
    wr, wi, fr, fi = _recombine_planes(s, m)
    if mode == "direct":
        outr, outi = recombine_batched_body(cr, ci, wr, wi, fr, fi)
    else:
        itp = mode == "interpret"
        bq = _block_q(q, 2 * m * ell, itp)
        bl = _block_l(ell, 2 * m, itp)
        outr, outi = recombine_twiddle_dft_batched(
            cr, ci, wr, wi, fr, fi, block_q=bq, block_l=bl, interpret=itp)
    return outr.reshape(q, s), outi.reshape(q, s)


# ---------------------------------------------------- fused bucket pipeline
def coded_bucket_fusable(s: int, m: int, n: int) -> bool:
    """Does the whole-bucket pipeline fit one kernel's VMEM working set?

    Per batch element the kernel keeps the request, the m message shards,
    the N coded spectra, the decoded shards and the output resident:
    roughly ``2 * (2*s + (m + n) * L)`` f32 values.  Degenerate
    factorizations (dense (B, B) DFT factor over budget) are excluded --
    the stage path's four-step falls back to the platform FFT there.
    """
    ell = s // m
    a, b = split_factor(ell)
    return ((2 * s + (m + n) * ell) <= 2 * _FUSED_MAX_ELEMS
            and b * b <= _FUSED_MAX_ELEMS)


def coded_bucket_streamable(s: int, m: int, n: int) -> bool:
    """Can the over-VMEM c2c bucket run as the ONE-launch streaming grid?

    The streaming kernel keeps only (block_q, A, block_b, m) /
    (block_q, block_a, B, m) tiles resident, so the batch working set
    drops out of the gate; what must still fit are the shared planes --
    the (A, A)/(B, B) DFT factors and the (m, s) pre-scrambled recombine
    twiddle -- plus a non-degenerate split (A > 1, else there is nothing
    to tile over).
    """
    ell = s // m
    a, b = split_factor(ell)
    return (a > 1
            and a * a <= _FUSED_MAX_ELEMS
            and b * b <= _FUSED_MAX_ELEMS
            and m * ell <= 4 * _FUSED_MAX_ELEMS)


def _streaming_blocks(kind: str, mode: str, **params):
    """(block_q, block_a, block_b) for a streaming launch: tuned entry if
    the autotune table has one, else the 256-tile default."""
    ent = autotune.lookup(kind, mode=mode, **params) or {}
    return (max(1, int(ent.get("block_q", 1) or 1)),
            int(ent.get("block_a", 256) or 256),
            int(ent.get("block_b", 256) or 256))


def coded_bucket(xr: jax.Array, xi: jax.Array,
                 dr: jax.Array, di: jax.Array,
                 gr: jax.Array, gi: jax.Array, s: int, *,
                 interpret: bool | None = None,
                 block_q: int | None = None,
                 precision: str = "f32"):
    """The service's whole-bucket hot path as ONE Pallas launch.

    ``xr, xi``: (q, s) request planes; ``dr, di``: (q, m, N) per-request
    scatter decode matrices; ``gr, gi``: (N, m) generator planes.  Returns
    (q, s) output planes -- interleave, fused encode+worker, decode matmul
    and recombine with no HBM round-trips between stages (DESIGN.md §6).
    Shapes beyond :func:`coded_bucket_fusable` route to the streaming
    double-buffered grid when :func:`coded_bucket_streamable` allows;
    ``block_q=None`` consults the autotune table, ``precision="bf16"``
    casts the shared planes (f32 accumulation throughout).
    """
    mode = _mode(interpret)
    q, s_ = xr.shape
    n, m = gr.shape
    ell = s // m
    a, b = split_factor(ell)
    dt = _plane_dtype(precision)
    planes = (*_dft_planes(a, dt), *_twiddle_planes(a, b, dt),
              *_dft_planes(b, dt), *_recombine_planes_scrambled(s, m, a, b, dt))
    if mode == "direct":
        return bucket_body(xr, xi, dr, di, gr, gi, *planes)
    itp = mode == "interpret"
    if not coded_bucket_fusable(s, m, n) and coded_bucket_streamable(s, m, n):
        bq, ba, bb = _streaming_blocks("bucket", mode, s=s, m=m, n=n)
        return coded_fft_bucket_streaming(
            xr, xi, dr, di, gr, gi, *planes,
            block_q=(block_q or bq), block_a=ba, block_b=bb, interpret=itp)
    if block_q is None:
        block_q = _tuned_block_q("bucket", q, 2 * s + (m + n) * ell, mode,
                                 s=s, m=m, n=n)
    return coded_fft_bucket(
        xr, xi, dr, di, gr, gi, *planes, block_q=block_q, interpret=itp)


def coded_bucket_masked(xr: jax.Array, xi: jax.Array, masks: jax.Array,
                        gr: jax.Array, gi: jax.Array, s: int, *,
                        interpret: bool | None = None,
                        block_q: int | None = None,
                        precision: str = "f32"):
    """:func:`coded_bucket` with IN-KERNEL decode matrices (DESIGN.md §8).

    ``masks``: (q, N) responder masks, shipped RAW -- subset selection
    (first-m responders) now happens inside the kernel
    (``subsets_from_masks_body``), then the Lagrange weights are built in
    VMEM per grid step and contracted immediately; nothing decode-related
    crosses the host boundary.  Same fused/streaming routing as
    :func:`coded_bucket`.
    """
    mode = _mode(interpret)
    q, _ = xr.shape
    n, m = gr.shape
    ell = s // m
    a, b = split_factor(ell)
    dt = _plane_dtype(precision)
    planes = (*_dft_planes(a, dt), *_twiddle_planes(a, b, dt),
              *_dft_planes(b, dt), *_recombine_planes_scrambled(s, m, a, b, dt))
    if mode == "direct":
        return bucket_body_masked(xr, xi, masks, gr, gi, *planes)
    itp = mode == "interpret"
    if not coded_bucket_fusable(s, m, n) and coded_bucket_streamable(s, m, n):
        bq, ba, bb = _streaming_blocks("bucket", mode, s=s, m=m, n=n)
        return coded_fft_bucket_streaming_masked(
            xr, xi, masks, gr, gi, *planes,
            block_q=(block_q or bq), block_a=ba, block_b=bb, interpret=itp)
    if block_q is None:
        block_q = _tuned_block_q("bucket", q, 2 * s + (m + n) * ell, mode,
                                 s=s, m=m, n=n)
    return coded_fft_bucket_masked(
        xr, xi, masks, gr, gi, *planes, block_q=block_q, interpret=itp)


def coded_bucket_direct(xr: jax.Array, xi: jax.Array,
                        dvr: jax.Array, dvi: jax.Array,
                        subsets: jax.Array,
                        gr: jax.Array, gi: jax.Array, s: int):
    """The off-TPU bucket executor: same fused pipeline, host lowerings.

    Same stage structure as :func:`coded_bucket`, with the worker DFT on
    the platform FFT and the decode as gathered compact ``(m, m)``
    matmuls (``dvr/dvi`` inverses + ``subsets`` responder indices from
    ``DecodeMatrixCache.compact``) -- the lowerings a Mosaic kernel cannot
    express but a CPU wants (DESIGN.md §6).  No VMEM gate: valid at any
    bucket shape.
    """
    m = gr.shape[1]
    return bucket_body_fftworker(
        xr, xi, dvr, dvi, subsets, gr, gi, *_recombine_planes(s, m))


# ------------------------------------------------- real-input (r2c) buckets
def coded_rbucket_fusable(s: int, m: int, n: int) -> bool:
    """VMEM gate for the fused r2c bucket kernel.

    Same accounting as :func:`coded_bucket_fusable` with HALF-length
    payloads (packed shards of L/2): the r2c working set is the real
    request + half spectra + (m + n) packed shards.
    """
    n2 = s // m // 2
    a, b = split_factor(n2)
    return ((2 * s + (m + n) * n2) <= 2 * _FUSED_MAX_ELEMS
            and b * b <= _FUSED_MAX_ELEMS)


def _r2c_postdecode_planes(s: int, m: int, dtype=np.float32):
    n2 = s // m // 2
    return (*_split_planes(2 * n2, dtype), *_recombine_planes(s, m, dtype)[:2],
            *_half_dft_planes(m, dtype))


def coded_rbucket(xr: jax.Array, dr: jax.Array, di: jax.Array,
                  gr: jax.Array, gi: jax.Array, s: int, *,
                  interpret: bool | None = None,
                  block_q: int | None = None,
                  precision: str = "f32"):
    """The r2c whole-bucket hot path (DESIGN.md §7) as ONE Pallas launch.

    ``xr``: (q, s) REAL request plane; ``dr, di``: (q, m, N) scatter decode
    matrices; ``gr, gi``: (N, m) generator planes.  Returns (q, s//2+1)
    half-spectrum planes.  Caller checks :func:`coded_rbucket_fusable`
    (the packed-butterfly pairing couples column p with n2-p, so the r2c
    pipeline has no column-local streaming variant -- see DESIGN.md §10).
    """
    mode = _mode(interpret)
    q, _ = xr.shape
    n, m = gr.shape
    n2 = s // m // 2
    a, b = split_factor(n2)
    dt = _plane_dtype(precision)
    planes = (*_dft_planes(a, dt), *_twiddle_planes(a, b, dt),
              *_dft_planes(b, dt), *_r2c_postdecode_planes(s, m, dt))
    if mode == "direct":
        return rbucket_body(xr, dr, di, gr, gi, *planes, s)
    itp = mode == "interpret"
    if block_q is None:
        block_q = _tuned_block_q("rbucket", q, 2 * s + (m + n) * n2, mode,
                                 s=s, m=m, n=n)
    return coded_rfft_bucket(xr, dr, di, gr, gi, *planes, s,
                             block_q=block_q, interpret=itp)


def coded_rbucket_masked(xr: jax.Array, masks: jax.Array,
                         gr: jax.Array, gi: jax.Array, s: int, *,
                         interpret: bool | None = None,
                         block_q: int | None = None,
                         precision: str = "f32"):
    """:func:`coded_rbucket` with in-kernel subset selection + Lagrange
    decode from raw ``(q, N)`` responder masks
    (cf. :func:`coded_bucket_masked`)."""
    mode = _mode(interpret)
    q, _ = xr.shape
    n, m = gr.shape
    n2 = s // m // 2
    a, b = split_factor(n2)
    dt = _plane_dtype(precision)
    planes = (*_dft_planes(a, dt), *_twiddle_planes(a, b, dt),
              *_dft_planes(b, dt), *_r2c_postdecode_planes(s, m, dt))
    if mode == "direct":
        return rbucket_body_masked(xr, masks, gr, gi, *planes, s)
    itp = mode == "interpret"
    if block_q is None:
        block_q = _tuned_block_q("rbucket", q, 2 * s + (m + n) * n2, mode,
                                 s=s, m=m, n=n)
    return coded_rfft_bucket_masked(xr, masks, gr, gi, *planes, s,
                                    block_q=block_q, interpret=itp)


def coded_rbucket_direct(xr: jax.Array, dvr: jax.Array, dvi: jax.Array,
                         subsets: jax.Array,
                         gr: jax.Array, gi: jax.Array, s: int):
    """Off-TPU r2c bucket executor: platform-FFT worker on the packed
    half-length shards, gathered compact decode, symmetry postdecode
    (cf. :func:`coded_bucket_direct`)."""
    m = gr.shape[1]
    return rbucket_body_fftworker(
        xr, dvr, dvi, subsets, gr, gi, *_r2c_postdecode_planes(s, m), s)


def rfft_postdecode_planar(hr: jax.Array, hi: jax.Array, s: int):
    """Stage-path r2c postdecode: decoded packed-spectrum planes
    ``(q, m, L/2)`` (natural order) -> half-spectrum planes
    ``(q, s//2+1)``.  Elementwise butterfly + one (m//2+1, m) contraction;
    runs as straight XLA in every mode (it is a fraction of the decode
    matmul's cost at any bucket shape)."""
    m = hr.shape[1]
    return half_postdecode_body(hr, hi, *_r2c_postdecode_planes(s, m), s)


# ------------------------------------------------ real-output (c2r) buckets
def _c2r_message_planes(s: int, m: int, dtype=np.float32):
    ctwr, ctwi, fpr, fpi = _recombine_planes(s, m, dtype, sign=1.0)
    pwr, pwi = _split_planes(s // m, dtype, sign=1.0)
    return fpr, fpi, ctwr, ctwi, pwr, pwi


def coded_irbucket_fusable(s: int, m: int, n: int) -> bool:
    """VMEM gate for the fused c2r bucket kernel.

    The c2r working set mirrors the r2c one (half-spectrum request +
    Hermitian intermediate + (m + n) packed half-length shards + real
    output), so the accounting is shared with
    :func:`coded_rbucket_fusable`.
    """
    return coded_rbucket_fusable(s, m, n)


def coded_irbucket(yr: jax.Array, yi: jax.Array,
                   dr: jax.Array, di: jax.Array,
                   gr: jax.Array, gi: jax.Array, s: int, *,
                   interpret: bool | None = None,
                   block_q: int | None = None,
                   precision: str = "f32"):
    """The c2r whole-bucket hot path (DESIGN.md §9) as ONE Pallas launch.

    ``yr, yi``: (q, s//2+1) half-spectrum request planes; ``dr, di``:
    (q, m, N) scatter decode matrices; ``gr, gi``: (N, m) generator
    planes.  Returns the (q, s) REAL output plane -- adjoint message
    butterfly, fused encode + half-length ifft worker (conj trick on
    planes), decode matmul and pair unpack with no HBM round-trips
    between stages.  Caller checks :func:`coded_irbucket_fusable`.
    """
    mode = _mode(interpret)
    q, _ = yr.shape
    n, m = gr.shape
    n2 = s // m // 2
    a, b = split_factor(n2)
    dt = _plane_dtype(precision)
    planes = (*_dft_planes(a, dt), *_twiddle_planes(a, b, dt),
              *_dft_planes(b, dt), *_c2r_message_planes(s, m, dt))
    if mode == "direct":
        return irbucket_body(yr, yi, dr, di, gr, gi, *planes, s)
    itp = mode == "interpret"
    if block_q is None:
        block_q = _tuned_block_q("irbucket", q, 2 * s + (m + n) * n2, mode,
                                 s=s, m=m, n=n)
    return coded_irfft_bucket(yr, yi, dr, di, gr, gi, *planes, s,
                              block_q=block_q, interpret=itp)


def coded_irbucket_masked(yr: jax.Array, yi: jax.Array, masks: jax.Array,
                          gr: jax.Array, gi: jax.Array, s: int, *,
                          interpret: bool | None = None,
                          block_q: int | None = None,
                          precision: str = "f32"):
    """:func:`coded_irbucket` with in-kernel subset selection + Lagrange
    decode from raw ``(q, N)`` responder masks
    (cf. :func:`coded_bucket_masked`) -- all four kinds share the §8
    zero-metadata device-resident decode path."""
    mode = _mode(interpret)
    q, _ = yr.shape
    n, m = gr.shape
    n2 = s // m // 2
    a, b = split_factor(n2)
    dt = _plane_dtype(precision)
    planes = (*_dft_planes(a, dt), *_twiddle_planes(a, b, dt),
              *_dft_planes(b, dt), *_c2r_message_planes(s, m, dt))
    if mode == "direct":
        return irbucket_body_masked(yr, yi, masks, gr, gi, *planes, s)
    itp = mode == "interpret"
    if block_q is None:
        block_q = _tuned_block_q("irbucket", q, 2 * s + (m + n) * n2, mode,
                                 s=s, m=m, n=n)
    return coded_irfft_bucket_masked(yr, yi, masks, gr, gi, *planes, s,
                                     block_q=block_q, interpret=itp)


def irfft_message_planar(yr: jax.Array, yi: jax.Array, s: int, m: int):
    """Stage-path c2r message stage: half-spectrum request planes
    ``(q, s//2+1)`` -> packed message planes ``(q, m, L/2)`` (the adjoint
    recombine butterfly + Hermitian pack, DESIGN.md §7)."""
    return ir_message_body(yr, yi, *_c2r_message_planes(s, m), s, m)


def irfft_unpack_planar(hr: jax.Array, hi: jax.Array):
    """Stage-path c2r postdecode: decoded packed interleave planes
    ``(q, m, L/2)`` -> the real output plane ``(q, s)``."""
    return ir_unpack_body(hr, hi)


def coded_irbucket_direct(yr: jax.Array, yi: jax.Array,
                          dvr: jax.Array, dvi: jax.Array,
                          subsets: jax.Array,
                          gr: jax.Array, gi: jax.Array, s: int):
    """Off-TPU c2r bucket executor: adjoint message stage on planes,
    platform-ifft worker on the packed half-length shards, gathered
    compact decode, relabel unpack.  Returns ONE real plane (q, s)."""
    m = gr.shape[1]
    return irbucket_body_fftworker(
        yr, yi, dvr, dvi, subsets, gr, gi, *_c2r_message_planes(s, m), s)


# ----------------------------------------------------- complex entry points
@functools.partial(jax.jit, static_argnames=("interpret",))
def _mds_apply_impl(g, c, interpret):
    mode = _mode(interpret)
    gr, gi = ref.planar(g)
    payload = c.shape[1:]
    flat = c.reshape(c.shape[0], -1)
    cr, ci = ref.planar(flat)
    if mode == "direct":
        outr, outi = cmatmul_body(gr, gi, cr, ci)
    else:
        itp = mode == "interpret"
        bl = _block_l(flat.shape[1], g.shape[0] + g.shape[1], itp)
        outr, outi = cmatmul(gr, gi, cr, ci, block_l=bl, interpret=itp)
    return ref.unplanar(outr, outi).reshape((g.shape[0],) + payload)


def mds_apply(g: jax.Array, c: jax.Array, *, interpret: bool | None = None):
    """Kernel-backed ``G @ c`` for MDS encode / decode-apply.

    ``g``: (n, m) complex code matrix; ``c``: (m, *payload).
    """
    return _mds_apply_impl(g, c, interpret)


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def _recombine_impl(c_hat, s, interpret):
    mode = _mode(interpret)
    m, ell = c_hat.shape
    cr, ci = ref.planar(c_hat)
    wr, wi, fr, fi = _recombine_planes(s, m)
    if mode == "direct":
        outr, outi = recombine_body(cr, ci, wr, wi, fr, fi)
    else:
        itp = mode == "interpret"
        bl = _block_l(ell, 2 * m, itp)
        outr, outi = recombine_twiddle_dft(
            cr, ci, wr, wi, fr, fi, block_l=bl, interpret=itp)
    return ref.unplanar(outr, outi).reshape(s)


def recombine_fused(c_hat: jax.Array, s: int, *, interpret: bool | None = None):
    """Kernel-backed master recombination: (m, s/m) decoded C -> X (s,)."""
    return _recombine_impl(c_hat, s, interpret)


# ------------------------------------------------------------- worker fns
def make_kernel_worker_fn(interpret: bool | None = None,
                          inverse: bool = False):
    """A ``CodedFFT.worker_fn`` that uses the Pallas four-step kernel.

    Satisfies the ``CodedPlan`` worker contract: transforms the LAST axis
    and maps over arbitrary leading axes.  All leading axes -- (workers,),
    (batch, workers) from the batched service scheduler, or (batch,
    n_local) under the distributed runtime -- are collapsed into the
    kernel's batch dimension, so a bucket of requests costs one Pallas
    launch instead of one per request.

    ``inverse=True`` yields the ifft worker of the inverse plans
    (DESIGN.md §7) via ``ifft(a) = conj(fft(conj(a))) / L`` -- one extra
    pair of sign flips on the imaginary plane, same kernel.
    """

    def worker_fn(a: jax.Array) -> jax.Array:
        lead, ell = a.shape[:-1], a.shape[-1]
        flat = a.reshape(-1, ell)
        if inverse:
            out = jnp.conj(
                fft_fourstep(jnp.conj(flat), interpret=interpret)) / ell
        else:
            out = fft_fourstep(flat, interpret=interpret)
        return out.reshape(lead + (ell,))

    return worker_fn


def make_kernel_fftn_fn(nd: int, interpret: bool | None = None):
    """An n-D worker fn: the four-step kernel swept over the last ``nd``
    axes (separability of the multidimensional DFT).  Used by the n-D and
    multi-input plans when the kernel backend is active."""
    worker_1d = make_kernel_worker_fn(interpret)

    def worker_fn(a: jax.Array) -> jax.Array:
        for ax in range(a.ndim - nd, a.ndim):
            a = jnp.moveaxis(worker_1d(jnp.moveaxis(a, ax, -1)), -1, ax)
        return a

    return worker_fn
