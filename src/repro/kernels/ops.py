"""Backend-dispatch layer: jit'd wrappers around the Pallas kernels.

These are the public entry points the core plans and the FFT service route
through (DESIGN.md §6).  They accept/return either natural complex arrays
or planar f32 planes, handle the planar split, and pick factorizations and
block sizes.

Execution-mode policy (the reason the kernel path is the *default* engine
and not a TPU-only demo).  Every kernel's math lives in a pure
``*_body`` function shared by two callers:

* **pallas** -- ``pl.pallas_call`` with VMEM-sized blocks; compiled on
  TPU, ``interpret=True`` elsewhere.  The parity tests pin
  ``interpret=True`` so every body is exercised through the real Pallas
  machinery on CPU in every PR.
* **direct** -- the body evaluated on the full batch as straight XLA.
  This is the off-TPU default (``interpret=None``): the interpret-mode
  grid emulation pays per-call buffer-copy overhead (~ms per bucket at
  service sizes) that would hand the hot path back to the jnp oracle,
  while the direct body is the identical math (bit-identical results)
  at zero overhead.

``interpret=None`` therefore means "compiled pallas on TPU, direct body
elsewhere"; an explicit ``interpret=True/False`` forces the Pallas call.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cmatmul import (
    bcmatmul,
    bcmatmul_body,
    cmatmul,
    cmatmul_body,
)
from repro.kernels.coded_pipeline import (
    bucket_body,
    bucket_body_fftworker,
    coded_fft_bucket,
)
from repro.kernels.fourstep_fft import (
    encode_fourstep_body,
    encode_fourstep_fused,
    fourstep_body,
    fourstep_fused,
    fourstep_stage1,
    fourstep_stage2,
    stage1_body,
    stage2_body,
)
from repro.kernels.recombine import (
    recombine_batched_body,
    recombine_body,
    recombine_twiddle_dft,
    recombine_twiddle_dft_batched,
)

__all__ = [
    "default_interpret",
    "kernel_backend_supported",
    "split_factor",
    "fft_fourstep",
    "fourstep_planar",
    "encode_worker",
    "decode_apply",
    "recombine_planar",
    "coded_bucket",
    "coded_bucket_direct",
    "coded_bucket_fusable",
    "mds_apply",
    "recombine_fused",
    "make_kernel_worker_fn",
    "make_kernel_fftn_fn",
]

# VMEM budget heuristic (TPU, compiled): fused kernel keeps ~4 (A,B) planes
# + 2 (A,A) + 2 (B,B) + 2 (A,B) twiddle planes resident; cap the fused path
# at the size where that stays under ~12 MB of the 16 MB VMEM.
_FUSED_MAX_ELEMS = 512 * 512
# Interpret-mode (host) block budget: collapse the batch into one grid step
# whenever a block stays under ~32 MB/plane -- the collapsed call traces the
# kernel body once and lowers to plain fused XLA matmuls.
_INTERPRET_BLOCK_ELEMS = 1 << 23


def default_interpret() -> bool:
    """Pallas interpret mode everywhere except real TPU backends."""
    return jax.default_backend() != "tpu"


def _mode(interpret: bool | None) -> str:
    """Resolve the execution mode: ``"compiled"`` | ``"interpret"`` |
    ``"direct"`` (see module docstring)."""
    if interpret is None:
        return "direct" if default_interpret() else "compiled"
    return "interpret" if interpret else "compiled"


def kernel_backend_supported(dtype) -> bool:
    """The planar kernels compute in f32 planes: complex64 plans only.

    complex128 plans (the numerics/reference tier) resolve to the jnp
    backend -- the dispatch rule in DESIGN.md §6.
    """
    return jnp.dtype(dtype) == jnp.dtype(jnp.complex64)


def split_factor(n: int) -> tuple[int, int]:
    """Factor ``n = a * b`` with a, b as close as possible (a <= b).

    MXU-friendliness: prefers multiples of 128 when available; for powers of
    two this returns (2^floor(k/2), 2^ceil(k/2)).  Primes fall back to
    (1, n): stage 1 degenerates to the identity and stage 2 is one dense
    DFT matmul.
    """
    a = int(math.isqrt(n))
    while a > 1 and n % a != 0:
        a -= 1
    return a, n // a


def _block_q(batch: int, per_elem: int, interpret: bool) -> int:
    """Batch elements per grid step under the active memory budget."""
    budget = _INTERPRET_BLOCK_ELEMS if interpret else _FUSED_MAX_ELEMS
    return max(1, min(batch, budget // max(per_elem, 1)))


def _block_l(total: int, rows: int, interpret: bool) -> int:
    """Payload columns per grid step for the streaming matmul kernels."""
    if interpret:
        return max(1, min(total, _INTERPRET_BLOCK_ELEMS // max(rows, 1)))
    return min(total, 512)


def _dft_planes(n: int, dtype=jnp.float32):
    jk = jnp.outer(jnp.arange(n), jnp.arange(n))
    ang = -2.0 * jnp.pi * (jk % n) / n
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def _twiddle_planes(a: int, b: int, dtype=jnp.float32):
    # W[c, b] = omega_{a*b}^{c*b}
    cb = jnp.outer(jnp.arange(a), jnp.arange(b))
    ang = -2.0 * jnp.pi * (cb % (a * b)) / (a * b)
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def _recombine_planes(s: int, m: int, dtype=jnp.float32):
    # recombine twiddle W[k, i] = omega_s^{ik} plus the length-m DFT planes
    ki = jnp.outer(jnp.arange(m), jnp.arange(s // m))
    ang = -2.0 * jnp.pi * (ki % s) / s
    return (jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype),
            *_dft_planes(m, dtype))


def _recombine_planes_scrambled(s: int, m: int, a: int, b: int,
                                dtype=jnp.float32):
    """Recombine planes with the twiddle permuted to the four-step payload
    order ``l' = c*B + d`` for natural ``l = c + d*A`` -- the bucket kernel
    carries that order through decode and unscrambles only at the output
    (kernels/coded_pipeline.py)."""
    twr, twi, fr, fi = _recombine_planes(s, m, dtype)
    perm = lambda t: jnp.transpose(
        t.reshape(m, b, a), (0, 2, 1)).reshape(m, a * b)
    return perm(twr), perm(twi), fr, fi


# ---------------------------------------------------------------- four-step
def fourstep_planar(xr: jax.Array, xi: jax.Array, *,
                    interpret: bool | None = None,
                    fused: bool | None = None):
    """Batched planar FFT along the last axis via the four-step kernels.

    ``xr, xi``: (batch, L) f32 planes.  Returns natural-order (batch, L)
    planes of ``fft(x, axis=-1)``.  ``fused=None`` picks the single-kernel
    path when the (A, B) matrix fits the VMEM budget, else the two-pass
    stage1/stage2 kernels.  Degenerate factorizations (prime or
    near-prime L, where the dense (B, B) DFT factor would dwarf an FFT's
    flops AND its plane would not fit VMEM) fall back to the platform FFT.
    """
    mode = _mode(interpret)
    batch, ell = xr.shape
    a, b = split_factor(ell)
    if b * b > _FUSED_MAX_ELEMS:
        z = jnp.fft.fft(xr + 1j * xi, axis=-1)
        return jnp.real(z).astype(xr.dtype), jnp.imag(z).astype(xr.dtype)
    if fused is None:
        fused = (a * b) <= _FUSED_MAX_ELEMS
    xr = xr.reshape(batch, a, b)
    xi = xi.reshape(batch, a, b)
    far, fai = _dft_planes(a)
    fbr, fbi = _dft_planes(b)
    wr, wi = _twiddle_planes(a, b)
    if mode == "direct":
        if fused:
            outr, outi = fourstep_body(xr, xi, far, fai, wr, wi, fbr, fbi)
        else:
            t1r, t1i = stage1_body(xr, xi, far, fai, wr, wi)
            outr, outi = stage2_body(t1r, t1i, fbr, fbi)
    else:
        itp = mode == "interpret"
        bq = _block_q(batch, a * b, itp)
        if fused:
            outr, outi = fourstep_fused(
                xr, xi, far, fai, wr, wi, fbr, fbi,
                block_q=bq, interpret=itp)
        else:
            t1r, t1i = fourstep_stage1(
                xr, xi, far, fai, wr, wi, block_q=bq, interpret=itp)
            outr, outi = fourstep_stage2(
                t1r, t1i, fbr, fbi, block_q=bq, interpret=itp)
    # out[c, d] holds X[c + d*A]  ->  transpose to (d, c) then flatten
    outr = jnp.swapaxes(outr, -1, -2).reshape(batch, ell)
    outi = jnp.swapaxes(outi, -1, -2).reshape(batch, ell)
    return outr, outi


@functools.partial(jax.jit, static_argnames=("interpret", "fused"))
def _fft_fourstep_impl(x, interpret, fused):
    xr, xi = ref.planar(x)
    outr, outi = fourstep_planar(xr, xi, interpret=interpret, fused=fused)
    return ref.unplanar(outr, outi)


def fft_fourstep(x: jax.Array, *, interpret: bool | None = None,
                 fused: bool | None = None) -> jax.Array:
    """Batched FFT along the last axis via the Pallas four-step kernel.

    ``x``: (..., L) complex; L is factored automatically.  Non-batched
    inputs are promoted.  Output matches ``jnp.fft.fft(x, axis=-1)`` up to
    f32 planar precision.
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    batch_shape = x.shape[:-1]
    ell = x.shape[-1]
    out = _fft_fourstep_impl(
        x.reshape(-1, ell), interpret, fused
    ).reshape(batch_shape + (ell,))
    return out[0] if squeeze else out


# ------------------------------------------------- fused encode + worker
def encode_worker(cr: jax.Array, ci: jax.Array,
                  gr: jax.Array, gi: jax.Array, *,
                  interpret: bool | None = None,
                  fused: bool | None = None):
    """Message planes -> coded worker spectra: ``B = fft(G @ c, axis=-1)``.

    ``cr, ci``: (q, m, L) planes of the message shards; ``gr, gi``: (n, m)
    generator planes.  Returns natural-order (q, n, L) planes.

    ``fused=None`` picks the single-kernel fused path (encode contraction
    in VMEM, m-shard DFTs -- an N/m flop saving over transforming coded
    shards) when the per-element footprint fits the VMEM budget, else the
    two-pass fallback: streamed cmatmul encode, then the four-step worker
    on the coded rows.
    """
    mode = _mode(interpret)
    q, m, ell = cr.shape
    n = gr.shape[0]
    a, b = split_factor(ell)
    if fused is None:
        # degenerate factorization (b*b over budget): two-pass, whose
        # four-step stage falls back to the platform FFT
        fused = ((m + n) * a * b <= 2 * _FUSED_MAX_ELEMS
                 and b * b <= _FUSED_MAX_ELEMS)
    if fused:
        planes = (*_dft_planes(a), *_twiddle_planes(a, b), *_dft_planes(b))
        if mode == "direct":
            br_, bi_ = encode_fourstep_body(
                cr.reshape(q, m, a, b), ci.reshape(q, m, a, b), gr, gi,
                *planes)
        else:
            itp = mode == "interpret"
            bq = _block_q(q, (m + n) * a * b, itp)
            br_, bi_ = encode_fourstep_fused(
                cr.reshape(q, m, a, b), ci.reshape(q, m, a, b), gr, gi,
                *planes, block_q=bq, interpret=itp)
        br_ = jnp.swapaxes(br_, -1, -2).reshape(q, n, ell)
        bi_ = jnp.swapaxes(bi_, -1, -2).reshape(q, n, ell)
        return br_, bi_
    # two-pass: encode via the streaming cmatmul (batch folded into the
    # payload columns -- G is shared), then the planar four-step worker
    tr = jnp.transpose(cr, (1, 0, 2)).reshape(m, q * ell)
    ti = jnp.transpose(ci, (1, 0, 2)).reshape(m, q * ell)
    if mode == "direct":
        er, ei = cmatmul_body(gr, gi, tr, ti)
    else:
        itp = mode == "interpret"
        bl = _block_l(q * ell, m + n, itp)
        er, ei = cmatmul(gr, gi, tr, ti, block_l=bl, interpret=itp)
    ar = jnp.transpose(er.reshape(n, q, ell), (1, 0, 2)).reshape(q * n, ell)
    ai = jnp.transpose(ei.reshape(n, q, ell), (1, 0, 2)).reshape(q * n, ell)
    br_, bi_ = fourstep_planar(ar, ai, interpret=interpret)
    return br_.reshape(q, n, ell), bi_.reshape(q, n, ell)


# ------------------------------------------------------------ decode apply
def decode_apply(dr: jax.Array, di: jax.Array,
                 br: jax.Array, bi: jax.Array, *,
                 interpret: bool | None = None):
    """Per-request decode matrices applied as one batched MXU matmul.

    ``dr, di``: (q, m, N) planes of scatter decode matrices (zero columns
    for stragglers -- DESIGN.md §6); ``br, bi``: (q, N, L) worker-result
    planes.  Returns (q, m, L) decoded sub-transform planes.
    """
    mode = _mode(interpret)
    if mode == "direct":
        return bcmatmul_body(dr, di, br, bi)
    itp = mode == "interpret"
    q, m, n = dr.shape
    ell = br.shape[-1]
    bq = _block_q(q, (m + n) * ell, itp)
    bl = _block_l(ell, m + n, itp)
    return bcmatmul(dr, di, br, bi, block_q=bq, block_l=bl, interpret=itp)


# -------------------------------------------------------------- recombine
def recombine_planar(cr: jax.Array, ci: jax.Array, s: int, *,
                     interpret: bool | None = None):
    """Batched master recombination on planes: (q, m, s/m) -> (q, s)."""
    mode = _mode(interpret)
    q, m, ell = cr.shape
    wr, wi, fr, fi = _recombine_planes(s, m)
    if mode == "direct":
        outr, outi = recombine_batched_body(cr, ci, wr, wi, fr, fi)
    else:
        itp = mode == "interpret"
        bq = _block_q(q, 2 * m * ell, itp)
        bl = _block_l(ell, 2 * m, itp)
        outr, outi = recombine_twiddle_dft_batched(
            cr, ci, wr, wi, fr, fi, block_q=bq, block_l=bl, interpret=itp)
    return outr.reshape(q, s), outi.reshape(q, s)


# ---------------------------------------------------- fused bucket pipeline
def coded_bucket_fusable(s: int, m: int, n: int) -> bool:
    """Does the whole-bucket pipeline fit one kernel's VMEM working set?

    Per batch element the kernel keeps the request, the m message shards,
    the N coded spectra, the decoded shards and the output resident:
    roughly ``2 * (2*s + (m + n) * L)`` f32 values.  Degenerate
    factorizations (dense (B, B) DFT factor over budget) are excluded --
    the stage path's four-step falls back to the platform FFT there.
    """
    ell = s // m
    a, b = split_factor(ell)
    return ((2 * s + (m + n) * ell) <= 2 * _FUSED_MAX_ELEMS
            and b * b <= _FUSED_MAX_ELEMS)


def coded_bucket(xr: jax.Array, xi: jax.Array,
                 dr: jax.Array, di: jax.Array,
                 gr: jax.Array, gi: jax.Array, s: int, *,
                 interpret: bool | None = None):
    """The service's whole-bucket hot path as ONE Pallas launch.

    ``xr, xi``: (q, s) request planes; ``dr, di``: (q, m, N) per-request
    scatter decode matrices; ``gr, gi``: (N, m) generator planes.  Returns
    (q, s) output planes -- interleave, fused encode+worker, decode matmul
    and recombine with no HBM round-trips between stages (DESIGN.md §6).
    Caller must check :func:`coded_bucket_fusable` first.
    """
    mode = _mode(interpret)
    q, s_ = xr.shape
    n, m = gr.shape
    ell = s // m
    a, b = split_factor(ell)
    planes = (*_dft_planes(a), *_twiddle_planes(a, b), *_dft_planes(b),
              *_recombine_planes_scrambled(s, m, a, b))
    if mode == "direct":
        return bucket_body(xr, xi, dr, di, gr, gi, *planes)
    itp = mode == "interpret"
    bq = _block_q(q, 2 * s + (m + n) * ell, itp)
    return coded_fft_bucket(
        xr, xi, dr, di, gr, gi, *planes, block_q=bq, interpret=itp)


def coded_bucket_direct(xr: jax.Array, xi: jax.Array,
                        dvr: jax.Array, dvi: jax.Array,
                        subsets: jax.Array,
                        gr: jax.Array, gi: jax.Array, s: int):
    """The off-TPU bucket executor: same fused pipeline, host lowerings.

    Same stage structure as :func:`coded_bucket`, with the worker DFT on
    the platform FFT and the decode as gathered compact ``(m, m)``
    matmuls (``dvr/dvi`` inverses + ``subsets`` responder indices from
    ``DecodeMatrixCache.compact``) -- the lowerings a Mosaic kernel cannot
    express but a CPU wants (DESIGN.md §6).  No VMEM gate: valid at any
    bucket shape.
    """
    m = gr.shape[1]
    return bucket_body_fftworker(
        xr, xi, dvr, dvi, subsets, gr, gi, *_recombine_planes(s, m))


# ----------------------------------------------------- complex entry points
@functools.partial(jax.jit, static_argnames=("interpret",))
def _mds_apply_impl(g, c, interpret):
    mode = _mode(interpret)
    gr, gi = ref.planar(g)
    payload = c.shape[1:]
    flat = c.reshape(c.shape[0], -1)
    cr, ci = ref.planar(flat)
    if mode == "direct":
        outr, outi = cmatmul_body(gr, gi, cr, ci)
    else:
        itp = mode == "interpret"
        bl = _block_l(flat.shape[1], g.shape[0] + g.shape[1], itp)
        outr, outi = cmatmul(gr, gi, cr, ci, block_l=bl, interpret=itp)
    return ref.unplanar(outr, outi).reshape((g.shape[0],) + payload)


def mds_apply(g: jax.Array, c: jax.Array, *, interpret: bool | None = None):
    """Kernel-backed ``G @ c`` for MDS encode / decode-apply.

    ``g``: (n, m) complex code matrix; ``c``: (m, *payload).
    """
    return _mds_apply_impl(g, c, interpret)


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def _recombine_impl(c_hat, s, interpret):
    mode = _mode(interpret)
    m, ell = c_hat.shape
    cr, ci = ref.planar(c_hat)
    wr, wi, fr, fi = _recombine_planes(s, m)
    if mode == "direct":
        outr, outi = recombine_body(cr, ci, wr, wi, fr, fi)
    else:
        itp = mode == "interpret"
        bl = _block_l(ell, 2 * m, itp)
        outr, outi = recombine_twiddle_dft(
            cr, ci, wr, wi, fr, fi, block_l=bl, interpret=itp)
    return ref.unplanar(outr, outi).reshape(s)


def recombine_fused(c_hat: jax.Array, s: int, *, interpret: bool | None = None):
    """Kernel-backed master recombination: (m, s/m) decoded C -> X (s,)."""
    return _recombine_impl(c_hat, s, interpret)


# ------------------------------------------------------------- worker fns
def make_kernel_worker_fn(interpret: bool | None = None):
    """A ``CodedFFT.worker_fn`` that uses the Pallas four-step kernel.

    Satisfies the ``CodedPlan`` worker contract: transforms the LAST axis
    and maps over arbitrary leading axes.  All leading axes -- (workers,),
    (batch, workers) from the batched service scheduler, or (batch,
    n_local) under the distributed runtime -- are collapsed into the
    kernel's batch dimension, so a bucket of requests costs one Pallas
    launch instead of one per request.
    """

    def worker_fn(a: jax.Array) -> jax.Array:
        lead, ell = a.shape[:-1], a.shape[-1]
        out = fft_fourstep(a.reshape(-1, ell), interpret=interpret)
        return out.reshape(lead + (ell,))

    return worker_fn


def make_kernel_fftn_fn(nd: int, interpret: bool | None = None):
    """An n-D worker fn: the four-step kernel swept over the last ``nd``
    axes (separability of the multidimensional DFT).  Used by the n-D and
    multi-input plans when the kernel backend is active."""
    worker_1d = make_kernel_worker_fn(interpret)

    def worker_fn(a: jax.Array) -> jax.Array:
        for ax in range(a.ndim - nd, a.ndim):
            a = jnp.moveaxis(worker_1d(jnp.moveaxis(a, ax, -1)), -1, ax)
        return a

    return worker_fn
