"""Per-shape kernel autotuner: measured block/variant tables with a JSON cache.

The ops-layer dispatchers used to pick block shapes from one hard-coded
heuristic (``_block_q`` / ``_block_l``) and the four-step always used the
balanced two-factor split.  Neither choice is stable across backends: on
CPU the platform FFT beats any dense-matmul factorization outright, in
interpret mode the best plan is "one giant block", and on TPU the right
(block_q, block_l) tiling depends on the bucket's VMEM working set.  This
module replaces the guesswork with a small measured table:

* **keys** -- ``"{kind}|k=v|..."`` with the shape params sorted, one table
  per execution mode (``direct`` / ``interpret`` / ``compiled``), one JSON
  cache file per jax backend (``autotune-{backend}.json``), so a table
  tuned on one machine class never leaks onto another.
* **entries** -- plain dicts: ``{"variant": "fused"|"two_pass"|"xla",
  "factors": [...], "block_q": int, "block_l": int, "bf16_ok": bool,
  "ms": float}``; every field optional, consumers take what they need.
* **search** -- :func:`tune_fourstep` / :func:`tune_bucket` time a handful
  of candidates (median of a few reps on real jitted calls) and record the
  winner.  Searches run from ``FFTService.warmup()`` or the bench harness,
  NEVER from a dispatcher: :func:`lookup` inside a jit trace is a pure
  dict read, so dispatch stays deterministic and trace-time cheap.
* **persistence** -- the winning table is written atomically after each
  search; the next process loads it and skips the search entirely (the
  warm path the autotune-cache round-trip test pins).

``REPRO_AUTOTUNE_CACHE`` overrides the cache directory (default
``~/.cache/coded-fft``); tests point it at a tmpdir.
"""

from __future__ import annotations

import json
import os
import pathlib
import tempfile
import time
from typing import Callable, Optional

import jax
import numpy as np

__all__ = [
    "cache_path",
    "clear",
    "key_of",
    "lookup",
    "record",
    "load_table",
    "save_table",
    "searches_run",
    "candidate_factor_plans",
    "tune_fourstep",
    "ensure_fourstep",
    "tune_bucket",
    "ensure_bucket",
]

SCHEMA_VERSION = 1

# in-memory tables, keyed by jax backend name; each maps key -> entry dict
_TABLES: dict[str, dict[str, dict]] = {}
_LOADED: set[str] = set()
_SEARCHES = 0  # lifetime search count (tests/CI assert the warm-skip path)


def _backend() -> str:
    return jax.default_backend()


def cache_path(backend: Optional[str] = None) -> pathlib.Path:
    """The JSON cache file for ``backend`` (default: the active one)."""
    root = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if not root:
        root = os.path.join(os.path.expanduser("~"), ".cache", "coded-fft")
    return pathlib.Path(root) / f"autotune-{backend or _backend()}.json"


def searches_run() -> int:
    """Lifetime number of measured searches (cache hits do not count)."""
    return _SEARCHES


def clear(memory_only: bool = True, backend: Optional[str] = None) -> None:
    """Drop the in-memory table (and optionally the on-disk cache)."""
    b = backend or _backend()
    _TABLES.pop(b, None)
    _LOADED.discard(b)
    if not memory_only:
        try:
            cache_path(b).unlink()
        except FileNotFoundError:
            pass


def load_table(backend: Optional[str] = None) -> dict[str, dict]:
    """The (lazily disk-loaded) table for ``backend``."""
    b = backend or _backend()
    if b not in _LOADED:
        table: dict[str, dict] = {}
        try:
            blob = json.loads(cache_path(b).read_text())
            if blob.get("version") == SCHEMA_VERSION:
                table = {str(k): dict(v)
                         for k, v in blob.get("entries", {}).items()}
        except (FileNotFoundError, json.JSONDecodeError, OSError,
                AttributeError, TypeError):
            table = {}  # missing/corrupt cache: start cold, never crash
        _TABLES.setdefault(b, {}).update(
            {k: v for k, v in table.items() if k not in _TABLES.get(b, {})})
        _LOADED.add(b)
    return _TABLES.setdefault(b, {})


def save_table(backend: Optional[str] = None) -> pathlib.Path:
    """Atomically persist the in-memory table for ``backend``."""
    b = backend or _backend()
    path = cache_path(b)
    path.parent.mkdir(parents=True, exist_ok=True)
    blob = {"version": SCHEMA_VERSION, "backend": b,
            "entries": load_table(b)}
    fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass
    return path


def key_of(kind: str, **params) -> str:
    """Canonical table key: kind plus sorted ``k=v`` shape params."""
    parts = [f"{k}={params[k]}" for k in sorted(params)]
    return "|".join([kind, *parts])


def lookup(kind: str, **params) -> Optional[dict]:
    """Pure table read (safe inside a jit trace -- no search, no I/O
    beyond the one lazy cache-file load per backend)."""
    return load_table().get(key_of(kind, **params))


def record(kind: str, entry: dict, persist: bool = True, **params) -> dict:
    """Store ``entry`` under the canonical key; persist unless told not."""
    load_table()[key_of(kind, **params)] = dict(entry)
    if persist:
        save_table()
    return entry


# ------------------------------------------------------------ measurement
def _time_ms(fn: Callable, args: tuple, reps: int) -> float:
    out = jax.block_until_ready(fn(*args))  # compile + warm
    del out
    ts = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e3)


# -------------------------------------------------------- four-step plans
def _balanced_split(n: int) -> tuple[int, int]:
    a = int(np.sqrt(n))
    while a > 1 and n % a != 0:
        a -= 1
    return a, n // a


def _split_to_radix(n: int, radix: int) -> Optional[list[int]]:
    """Factor ``n`` into factors <= ``radix`` by greedily peeling the
    largest divisor; None when a prime factor exceeds the radix."""
    out: list[int] = []
    while n > 1:
        f = min(n, radix)
        while f > 1 and n % f != 0:
            f -= 1
        if f == 1:
            return None  # prime beyond the radix
        out.append(f)
        n //= f
    return out


def candidate_factor_plans(ell: int, max_plans: int = 5) -> list[list[int]]:
    """Candidate radix plans for a length-``ell`` multistep four-step.

    Always includes the classic balanced two-factor split; deeper plans
    cap the largest dense DFT factor at 64/32/16 (sum-of-factors is the
    flop count per element, smaller caps trade flops for more stages).
    """
    plans: list[list[int]] = []
    a, b = _balanced_split(ell)
    if a > 1:
        plans.append([a, b])
    for radix in (64, 32, 16):
        p = _split_to_radix(ell, radix)
        if p and len(p) >= 2 and p not in plans:
            plans.append(p)
    return plans[:max_plans] or [[1, ell]]


def tune_fourstep(ell: int, batch: int = 4, mode: str = "direct", *,
                  reps: int = 5, factor_plans: Optional[list] = None,
                  include_xla: Optional[bool] = None,
                  persist: bool = True) -> dict:
    """Measure four-step variants at length ``ell`` and record the winner.

    Candidates: ``("fused", factors)`` for each radix plan,
    ``("two_pass", None)``, and -- where the dispatcher may legally use the
    platform FFT, i.e. every non-Pallas path -- ``("xla", None)``.  The
    winning ``{"variant", "factors", "ms"}`` entry is recorded under
    ``fourstep|L=...|mode=...`` and (by default) persisted.
    """
    global _SEARCHES
    from repro.kernels import ops  # deferred: ops imports this module

    _SEARCHES += 1
    interpret = {"direct": None, "interpret": True, "compiled": False}[mode]
    if include_xla is None:
        include_xla = mode == "direct"
    rng = np.random.default_rng(0)
    xr = jax.numpy.asarray(rng.standard_normal((batch, ell)), jax.numpy.float32)
    xi = jax.numpy.asarray(rng.standard_normal((batch, ell)), jax.numpy.float32)

    cands: list[tuple[str, Optional[list[int]]]] = []
    for f in (factor_plans if factor_plans is not None
              else candidate_factor_plans(ell)):
        cands.append(("fused", list(f)))
    cands.append(("two_pass", None))
    if include_xla:
        cands.append(("xla", None))

    best: Optional[dict] = None
    for variant, factors in cands:
        fn = jax.jit(_fourstep_candidate_fn(variant, factors, interpret))
        try:
            ms = _time_ms(fn, (xr, xi), reps)
        except Exception:
            continue  # a candidate that fails to lower is just skipped
        if best is None or ms < best["ms"]:
            best = {"variant": variant, "ms": ms}
            if factors is not None:
                best["factors"] = factors
    if best is None:  # every candidate failed: record the safe default
        best = {"variant": "two_pass", "ms": float("nan")}
    return record("fourstep", best, persist=persist, L=ell, mode=mode)


def _fourstep_candidate_fn(variant, factors, interpret):
    from repro.kernels import ops

    def fn(xr, xi):
        return ops.fourstep_planar(xr, xi, interpret=interpret,
                                   variant=variant, factors=factors)

    return fn


def ensure_fourstep(ell: int, batch: int = 4, mode: str = "direct",
                    **kw) -> dict:
    """Warm path: return the recorded entry, searching only on a miss."""
    ent = lookup("fourstep", L=ell, mode=mode)
    if ent is not None:
        return ent
    return tune_fourstep(ell, batch, mode, **kw)


# ------------------------------------------------------------ bucket tiles
def tune_bucket(kind: str, s: int, m: int, n: int, q: int = 4, *,
                mode: str = "interpret", reps: int = 3,
                block_qs: Optional[list[int]] = None,
                persist: bool = True) -> dict:
    """Measure candidate batch-block sizes for a whole-bucket kernel.

    ``kind``: ``"bucket" | "rbucket" | "irbucket"``.  Runs the masked
    whole-bucket dispatcher (the service hot path) with forced ``block_q``
    candidates and records the winner under
    ``{kind}|s=..|m=..|n=..|mode=..``.  Only meaningful for the Pallas
    modes -- the direct path has no grid -- but callable anywhere.
    """
    global _SEARCHES
    from repro.kernels import ops

    _SEARCHES += 1
    interpret = {"direct": None, "interpret": True, "compiled": False}[mode]
    rng = np.random.default_rng(0)
    jnp = jax.numpy
    masks = np.zeros((q, n), bool)
    masks[:, :m] = True
    masks = jnp.asarray(masks)
    gr = jnp.asarray(rng.standard_normal((n, m)), jnp.float32)
    gi = jnp.asarray(rng.standard_normal((n, m)), jnp.float32)
    if block_qs is None:
        block_qs = sorted({1, max(1, q // 2), q})

    def make(bq):
        if kind == "rbucket":
            xb = jnp.asarray(rng.standard_normal((q, s)), jnp.float32)
            fn = jax.jit(lambda x, mk: ops.coded_rbucket_masked(
                x, mk, gr, gi, s, interpret=interpret, block_q=bq))
            return fn, (xb, masks)
        if kind == "irbucket":
            sh = s // 2 + 1
            yr = jnp.asarray(rng.standard_normal((q, sh)), jnp.float32)
            yi = jnp.asarray(rng.standard_normal((q, sh)), jnp.float32)
            fn = jax.jit(lambda a, b, mk: ops.coded_irbucket_masked(
                a, b, mk, gr, gi, s, interpret=interpret, block_q=bq))
            return fn, (yr, yi, masks)
        xr = jnp.asarray(rng.standard_normal((q, s)), jnp.float32)
        xi = jnp.asarray(rng.standard_normal((q, s)), jnp.float32)
        fn = jax.jit(lambda a, b, mk: ops.coded_bucket_masked(
            a, b, mk, gr, gi, s, interpret=interpret, block_q=bq))
        return fn, (xr, xi, masks)

    best: Optional[dict] = None
    for bq in block_qs:
        fn, args = make(int(bq))
        try:
            ms = _time_ms(fn, args, reps)
        except Exception:
            continue
        if best is None or ms < best["ms"]:
            best = {"block_q": int(bq), "ms": ms}
    if best is None:
        best = {"block_q": 1, "ms": float("nan")}
    return record(kind, best, persist=persist, s=s, m=m, n=n, mode=mode)


def ensure_bucket(kind: str, s: int, m: int, n: int, q: int = 4,
                  mode: str = "interpret", **kw) -> dict:
    """Warm path: recorded bucket entry, searching only on a miss."""
    ent = lookup(kind, s=s, m=m, n=n, mode=mode)
    if ent is not None:
        return ent
    return tune_bucket(kind, s, m, n, q, mode=mode, **kw)
