"""Whisper-medium [audio] — enc-dec, 24+24L d_model=1024 16H (kv=16)
d_ff=4096 vocab=51865.  Conv frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, T, d) per the assignment instructions.
[arXiv:2212.04356]

Pre-LN transformer, LayerNorm (not RMS), GELU MLP, learned/sinusoidal
positions, no RoPE.  decode_32k / prefill_32k use a synthetic 32k-frame
encoder sequence (the real model caps at 1500 frames — backbone-only
benchmark, documented in DESIGN.md §4).
"""

import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,                 # decoder layers
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    mlp_variant="gelu",
    norm="ln",
    tie_embeddings=True,
    frontend="audio_frames",
    notes="enc-dec; conv frontend stubbed to precomputed frame embeddings",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="whisper-medium-reduced",
    n_layers=2,
    encoder_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
)
