"""MiniCPM-2B [dense] — 40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753,
llama-like with muP-style scaling + WSD schedule.  [arXiv:2404.06395; hf]

MiniCPM specifics implemented: scale_emb=12 on the embedding output,
residual branch scale scale_depth/sqrt(L) = 1.4/sqrt(40), logits divided by
d_model/dim_base = 2304/256 = 9, tied embeddings.  The WSD (warmup-stable-
decay) LR schedule lives in optim/schedules.py.
"""

import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    mlp_variant="swiglu",
    tie_embeddings=True,
    emb_multiplier=12.0,
    logit_divisor=2304 / 256,
    depth_scale=1.4,
    notes="WSD schedule (optim/schedules.py); muP-ish scaling",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="minicpm-2b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    logit_divisor=64 / 256,
)
