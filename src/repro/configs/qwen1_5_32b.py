"""Qwen1.5-32B [dense] — 64L d_model=5120 40H (GQA kv=40 == MHA) d_ff=27392
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-32B family; hf-verified small sibling]

decode_32k at batch 128 needs 5.5 TB of bf16 KV (64L x 40 kv-heads x 128) —
exceeds the 4 TB single-pod HBM — so this config enables int8 KV
quantization for decode cells (2.75 TB; documented in EXPERIMENTS.md).
"""

import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    mlp_variant="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    kv_quant_decode=True,
    notes="QKV bias; MHA (kv=40)",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="qwen1.5-32b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    kv_quant_decode=False,
)
