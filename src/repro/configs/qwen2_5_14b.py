"""Qwen2.5-14B [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064, QKV bias.  [hf:Qwen/Qwen2.5 family; hf-verified small sibling]
"""

import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    mlp_variant="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    notes="GQA kv=8; QKV bias",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="qwen2.5-14b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
)
