"""Llama-4 Maverick 400B-A17B [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 (expert) vocab=202048, MoE 128 experts top-1 + 1 shared expert,
MoE on every second layer (interleave_moe_layer_step=2), early fusion.
[hf:meta-llama/Llama-4-* family]

Memory note: ~400B total params.  bf16 params (0.8 TB) + f32 Adam state
(3.2 TB) exceeds a 256-chip pod, so train cells use the int8 param-shaped quantized
optimizer state (optim/adamw.py) — 0.8 + 0.85 TB, fits with margin.
"""

import dataclasses

from repro.configs import ArchConfig, MoESettings

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,                   # dense layers' FFN width
    vocab_size=202048,
    mlp_variant="swiglu",
    rope_theta=500_000.0,
    moe=MoESettings(
        num_experts=128,
        top_k=1,
        d_ff_expert=8192,
        interleave_step=2,         # alternate dense / MoE
        num_shared_experts=1,
    ),
    notes="MoE 128e top-1 + shared expert, alternating layers; early fusion",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="llama4-maverick-reduced",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=256,
    moe=MoESettings(
        num_experts=4, top_k=1, d_ff_expert=128, interleave_step=2,
        num_shared_experts=1,
    ),
)
