"""DBRX-132B [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4 (fine-grained).  [hf:databricks/dbrx-base]
"""

import dataclasses

from repro.configs import ArchConfig, MoESettings

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    mlp_variant="swiglu",
    rope_theta=500_000.0,
    moe=MoESettings(
        num_experts=16,
        top_k=4,
        d_ff_expert=10752,
        interleave_step=1,
    ),
    notes="16 experts top-4, every layer MoE",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="dbrx-132b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    moe=MoESettings(num_experts=4, top_k=2, d_ff_expert=128, interleave_step=1),
)
