"""RecurrentGemma-9B [hybrid] — 38L d_model=4096 16H (MQA kv=1, head_dim 256)
d_ff=12288 vocab=256000.  RG-LRU + local attention, pattern (rec, rec, attn).
[arXiv:2402.19427 (Griffin) + RecurrentGemma report]

38 = 12 x (rec, rec, attn) + 2 trailing rec layers.  Local attention window
2048; RG-LRU width = d_model; GeGLU MLP; sqrt(d) embedding scale.
Supports long_500k: state is O(1), attention cache bounded by the window.
"""

import dataclasses
import math

from repro.configs import ArchConfig, RecurrentSettings

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    mlp_variant="geglu",
    tie_embeddings=True,
    emb_multiplier=math.sqrt(4096.0),
    attn_window=2048,
    recurrent=RecurrentSettings(
        d_rnn=4096,
        conv_width=4,
        block_pattern=("rec", "rec", "attn"),
    ),
    supports_long_context=True,
    notes="RG-LRU + local attn 1:2; window 2048",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="recurrentgemma-9b-reduced",
    n_layers=5,                   # (rec, rec, attn) + 2 rec tail
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    emb_multiplier=math.sqrt(64.0),
    attn_window=16,
    recurrent=RecurrentSettings(d_rnn=64, conv_width=4),
)
