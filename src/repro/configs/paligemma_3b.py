"""PaliGemma-3B [vlm] — SigLIP vision tower (STUB) + Gemma-2B backbone:
18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216.  [arXiv:2407.07726; hf]

The SigLIP frontend is a stub per the assignment: ``input_specs`` provides
256 precomputed patch embeddings (B, 256, d_model) prepended to the text
tokens with PaliGemma's prefix-LM mask (bidirectional prefix, causal suffix).
"""

import dataclasses
import math

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    mlp_variant="geglu",
    tie_embeddings=True,
    emb_multiplier=math.sqrt(2048.0),
    num_prefix_tokens=256,
    frontend="vision_patches",
    notes="SigLIP stub + gemma backbone; prefix-LM attention",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="paligemma-3b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    emb_multiplier=math.sqrt(64.0),
    num_prefix_tokens=8,
)
