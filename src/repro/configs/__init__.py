"""Architecture & shape registry.

Every assigned architecture is a frozen ``ArchConfig`` in its own module
(exact public-literature numbers), registered here under its ``--arch`` id.
``REDUCED`` is the same-family small config used by CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

__all__ = [
    "ArchConfig",
    "MoESettings",
    "RWKVSettings",
    "RecurrentSettings",
    "ShapeConfig",
    "SHAPES",
    "ARCH_IDS",
    "get_config",
    "get_reduced_config",
    "iter_cells",
    "cell_runnable",
]


@dataclasses.dataclass(frozen=True)
class MoESettings:
    num_experts: int
    top_k: int
    d_ff_expert: int
    interleave_step: int = 1      # 1 = every layer MoE; 2 = alternate dense/MoE
    num_shared_experts: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class RWKVSettings:
    head_size: int = 64
    decay_lora: int = 64
    gate_lora: int = 64
    mix_lora: int = 32


@dataclasses.dataclass(frozen=True)
class RecurrentSettings:
    """Griffin/RG-LRU hybrid settings."""

    d_rnn: int
    conv_width: int = 4
    block_pattern: tuple[str, ...] = ("rec", "rec", "attn")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    mlp_variant: str = "swiglu"    # swiglu | geglu | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    emb_multiplier: float = 1.0    # gemma: sqrt(d_model); minicpm: 12
    logit_divisor: float = 1.0     # minicpm: d_model / 256
    depth_scale: Optional[float] = None  # minicpm residual scale: v/sqrt(L)
    attn_window: Optional[int] = None
    logit_cap: Optional[float] = None
    norm: str = "rms"              # rms | ln
    moe: Optional[MoESettings] = None
    rwkv: Optional[RWKVSettings] = None
    recurrent: Optional[RecurrentSettings] = None
    encoder_layers: int = 0        # enc-dec only
    num_prefix_tokens: int = 0     # vlm: SigLIP patch count (stub frontend)
    frontend: Optional[str] = None # "audio_frames" | "vision_patches" | None
    supports_long_context: bool = False
    kv_quant_decode: bool = False  # int8 KV for decode cells (memory fit)
    remat: str = "full"
    notes: str = ""

    @property
    def moe_layer_flags(self) -> tuple[bool, ...]:
        if self.moe is None:
            return tuple(False for _ in range(self.n_layers))
        step = self.moe.interleave_step
        # HF llama4 convention: every `step`-th layer is MoE (offset step-1)
        return tuple((i % step) == (step - 1) for i in range(self.n_layers))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_MODULES = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen1.5-32b": "qwen1_5_32b",
    "minicpm-2b": "minicpm_2b",
    "qwen2.5-14b": "qwen2_5_14b",
    "gemma-2b": "gemma_2b",
    "whisper-medium": "whisper_medium",
    "dbrx-132b": "dbrx_132b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "rwkv6-3b": "rwkv6_3b",
    "paligemma-3b": "paligemma_3b",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).CONFIG


def get_reduced_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).REDUCED


def cell_runnable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is this (arch x shape) cell runnable, and why not if not."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full quadratic attention at 512k context (see DESIGN.md §4)"
    return True, ""


def iter_cells():
    """All 40 (arch, shape) cells with runnability verdicts."""
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for shape in SHAPES.values():
            ok, reason = cell_runnable(cfg, shape)
            yield arch_id, shape.name, ok, reason
