"""RWKV6-3B "Finch" [ssm] — 32L d_model=2560, attention-free, d_ff=8960
vocab=65536, data-dependent decay.  [arXiv:2404.05892; hf]

head_size 64 -> 40 heads; token-shift DDLerp mixing; decay/gate LoRAs.
Supports long_500k: recurrent state is O(1) in sequence length.
"""

import dataclasses

from repro.configs import ArchConfig, RWKVSettings

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,                  # d_model / head_size
    n_kv_heads=40,
    head_dim=64,                 # RWKV head_size
    d_ff=8960,
    vocab_size=65536,
    mlp_variant="relu2",         # RWKV channel-mix uses squared ReLU
    norm="ln",
    rwkv=RWKVSettings(head_size=64, decay_lora=64, gate_lora=64, mix_lora=32),
    supports_long_context=True,
    notes="Finch: data-dependent decay; attention-free",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="rwkv6-3b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    rwkv=RWKVSettings(head_size=16, decay_lora=16, gate_lora=16, mix_lora=8),
)
