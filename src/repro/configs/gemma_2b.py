"""Gemma-2B [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000,
GeGLU, head_dim=256, sqrt(d_model) embedding scaling.  [arXiv:2403.08295; hf]
"""

import dataclasses
import math

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp_variant="geglu",
    tie_embeddings=True,
    emb_multiplier=math.sqrt(2048.0),
    notes="GeGLU; MQA; head_dim 256; zero-centered RMSNorm",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="gemma-2b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    emb_multiplier=math.sqrt(64.0),
)
