from repro.checkpoint.store import (
    AsyncCheckpointer,
    gc_checkpoints,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = ["AsyncCheckpointer", "gc_checkpoints", "latest_step",
           "restore_checkpoint", "save_checkpoint"]
