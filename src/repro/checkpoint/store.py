"""Atomic sharded checkpointing with auto-resume.

Layout per step::

    <dir>/step_000123/
        shard_00000.npz        flattened leaf arrays (this host's slice)
        MANIFEST.json          treedef paths, shapes, dtypes, host count,
                               written LAST -> presence == checkpoint complete

Writes go to ``step_XXX.tmp.<pid>`` and are renamed into place only after
the manifest is fsynced, so a killed writer can never leave a checkpoint
that ``latest_step`` would pick up -- restart-safe by construction.
Multi-host: each process writes its own ``shard_<proc>.npz``; process 0
writes the manifest after a barrier (single-host here, but the layout and
the completeness protocol are the production ones).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer", "gc_checkpoints"]

_MANIFEST = "MANIFEST.json"

# numpy's .npz cannot round-trip ml_dtypes extension types (they load back
# as raw void); store them viewed as same-width uints, restore via manifest.
_EXT_DTYPES = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
    "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
}


def _to_disk(a: np.ndarray) -> np.ndarray:
    name = a.dtype.name
    if name in _EXT_DTYPES:
        return a.view(_EXT_DTYPES[name][0])
    return a


def _from_disk(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXT_DTYPES:
        return a.view(_EXT_DTYPES[dtype_name][1])
    return a


def _paths_and_leaves(tree) -> tuple[list[str], list[Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    paths = [jax.tree_util.keystr(k) for k, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def save_checkpoint(directory: str, step: int, tree, *, metadata: Optional[dict] = None,
                    process_index: int = 0, keep_last: Optional[int] = None) -> str:
    """Write ``tree`` atomically; returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = _step_dir(directory, step)
    tmp = f"{final}.tmp.{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    paths, leaves = _paths_and_leaves(tree)
    np_leaves = [np.asarray(v) for v in leaves]
    arrays = {f"leaf_{i}": _to_disk(v) for i, v in enumerate(np_leaves)}
    np.savez(os.path.join(tmp, f"shard_{process_index:05d}.npz"), **arrays)

    manifest = {
        "step": step,
        "paths": paths,
        "shapes": [list(np.shape(v)) for v in np_leaves],
        "dtypes": [v.dtype.name for v in np_leaves],
        "process_count": 1,
        "metadata": metadata or {},
    }
    mpath = os.path.join(tmp, _MANIFEST)
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    if keep_last is not None:
        gc_checkpoints(directory, keep_last)
    return final


def latest_step(directory: str) -> Optional[int]:
    """Newest step with a complete manifest (ignores torn .tmp writes)."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(("tmp",)) and "." not in name:
            if os.path.exists(os.path.join(directory, name, _MANIFEST)):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like, *, step: Optional[int] = None):
    """Restore into the structure of ``tree_like``.  Returns (step, tree).

    ``tree_like`` provides the treedef (values may be arrays or
    ShapeDtypeStructs); leaf order must match the saved flattening order.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {directory}")
    cdir = _step_dir(directory, step)
    with open(os.path.join(cdir, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(cdir, "shard_00000.npz"))

    paths, _ = _paths_and_leaves(tree_like)
    if paths != manifest["paths"]:
        raise ValueError(
            "checkpoint tree structure mismatch:\n"
            f"  saved    : {manifest['paths'][:5]}... ({len(manifest['paths'])} leaves)\n"
            f"  restoring: {paths[:5]}... ({len(paths)} leaves)")
    leaves = [
        jnp.asarray(_from_disk(data[f"leaf_{i}"], manifest["dtypes"][i]))
        for i in range(len(paths))
    ]
    treedef = jax.tree_util.tree_structure(tree_like)
    return step, jax.tree_util.tree_unflatten(treedef, leaves)


def gc_checkpoints(directory: str, keep_last: int) -> None:
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(directory)
        if n.startswith("step_") and "." not in n
        and os.path.exists(os.path.join(directory, n, _MANIFEST)))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(_step_dir(directory, s), ignore_errors=True)
    # also clear torn tmp dirs
    for n in os.listdir(directory):
        if ".tmp." in n:
            shutil.rmtree(os.path.join(directory, n), ignore_errors=True)


class AsyncCheckpointer:
    """Overlap checkpoint writes with the next training steps.

    ``save`` snapshots to host memory synchronously (device_get), then a
    daemon thread does the (slow) disk write; ``wait`` joins before the
    next save or at shutdown, so at most one write is in flight and a save
    is never silently dropped.
    """

    def __init__(self, directory: str, keep_last: Optional[int] = 3):
        self.directory = directory
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, metadata: Optional[dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))

        def _write():
            try:
                save_checkpoint(self.directory, step, host_tree,
                                metadata=metadata, keep_last=self.keep_last)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
