"""The jitted training step: microbatched grads -> clip -> AdamW.

``make_train_step`` closes over the model + optimizer and returns a pure
``(state, batch) -> (state, metrics)`` suitable for ``jax.jit`` with
explicit in/out shardings (launch/train.py, launch/dryrun.py).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.model_factory import BuiltModel
from repro.optim.adamw import Optimizer
from repro.optim.grad_utils import accumulate_grads, clip_by_global_norm
from repro.training.train_state import TrainState

__all__ = ["make_train_step"]


def make_train_step(model: BuiltModel, optimizer: Optimizer, *,
                    n_micro: int = 1, clip_norm: float = 1.0) -> Callable:
    loss_fn = model.loss

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        loss, metrics, grads = accumulate_grads(
            loss_fn, state.params, batch, n_micro)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = optimizer.update(
            state.params, grads, state.opt_state, state.step)
        new_state = TrainState(step=state.step + 1, params=new_params,
                               opt_state=new_opt)
        out = {"loss": loss.astype(jnp.float32),
               "grad_norm": gnorm.astype(jnp.float32)}
        for k, v in (metrics or {}).items():
            out[k] = jnp.asarray(v, jnp.float32)
        return new_state, out

    return train_step
