from repro.training.train_state import (
    TrainState,
    abstract_train_state,
    init_train_state,
    train_state_pspecs,
)
from repro.training.train_step import make_train_step
from repro.training.trainer import Trainer, TrainerConfig

__all__ = ["TrainState", "abstract_train_state", "init_train_state",
           "train_state_pspecs", "make_train_step", "Trainer", "TrainerConfig"]
