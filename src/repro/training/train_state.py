"""Training state: params + optimizer state + step, with the sharding plan
and abstract (ShapeDtypeStruct) mirrors the dry-run lowers against."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.params import Spec, abstract_params, param_pspecs
from repro.optim.adamw import Optimizer, QuantMoment, quantize_moment

__all__ = ["TrainState", "init_train_state", "abstract_train_state",
           "train_state_pspecs"]


@dataclasses.dataclass
class TrainState:
    step: Any
    params: Any
    opt_state: Any

    def tree(self) -> dict:
        return {"step": self.step, "params": self.params, "opt_state": self.opt_state}

    @classmethod
    def from_tree(cls, t: dict) -> "TrainState":
        return cls(step=t["step"], params=t["params"], opt_state=t["opt_state"])


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.step, s.params, s.opt_state), None),
    lambda _, c: TrainState(step=c[0], params=c[1], opt_state=c[2]),
)


def init_train_state(specs, optimizer: Optimizer, key: jax.Array) -> TrainState:
    from repro.models.params import init_params

    params = init_params(specs, key)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt_state=optimizer.init(params))


def _moment_abstract(p: jax.ShapeDtypeStruct, quantized: bool):
    from repro.optim.adamw import moment_block

    if not quantized:
        return {"m": jax.ShapeDtypeStruct(p.shape, jnp.float32),
                "v": jax.ShapeDtypeStruct(p.shape, jnp.float32)}
    work = p.shape if p.shape else (1,)
    b = moment_block(work[-1])
    qm = lambda: QuantMoment(
        q=jax.ShapeDtypeStruct(work, jnp.int8),
        scale=jax.ShapeDtypeStruct(work[:-1] + (work[-1] // b,), jnp.float32))
    return {"m": qm(), "v": qm()}


def abstract_train_state(specs, optimizer: Optimizer) -> TrainState:
    """ShapeDtypeStruct mirror of a fresh TrainState (no allocation)."""
    aparams = abstract_params(specs)
    quant = optimizer.config.quantized_state
    mu = jax.tree.map(lambda p: _moment_abstract(p, quant), aparams,
                      is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        params=aparams,
        opt_state={"count": jax.ShapeDtypeStruct((), jnp.int32), "mu": mu},
    )


def _moment_pspec(spec: Spec, ps: P, quantized: bool, mesh=None):
    if not quantized:
        return {"m": ps, "v": ps}
    # Param-shaped int8 q: EXACTLY the param's sharding (no resharding in
    # the update).  Scales: same lead axes, block axis replicated.
    ndim = max(len(spec.shape), 1)
    entries = list(ps) + [None] * (ndim - len(ps))
    scale_spec = P(*entries[:-1], None)
    return {"m": QuantMoment(q=ps, scale=scale_spec),
            "v": QuantMoment(q=ps, scale=scale_spec)}


def train_state_pspecs(specs, optimizer: Optimizer, rules=None, mesh=None) -> TrainState:
    """PartitionSpec tree mirroring TrainState (feeds jit in/out_shardings)."""
    pspecs = param_pspecs(specs, rules)
    quant = optimizer.config.quantized_state

    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, Spec))
    flat_ps, treedef = jax.tree.flatten(pspecs, is_leaf=lambda x: isinstance(x, P))
    mu = treedef.unflatten([
        _moment_pspec(s, ps, quant, mesh) for s, ps in zip(flat_specs, flat_ps)
    ])
    return TrainState(
        step=P(),
        params=pspecs,
        opt_state={"count": P(), "mu": mu},
    )
