"""Fault-tolerant training loop: checkpoint/restart + async saves.

The loop is deliberately boring -- all cleverness lives below it (coded
aggregation, compression, sharding) or beside it (AsyncCheckpointer).  Key
properties, each covered by tests:

* **restart-safe**: auto-resumes from the newest complete checkpoint;
  synthetic data is random-access by step, so the resumed run consumes
  exactly the batches the killed run would have -- bit-exact continuation.
* **async checkpointing**: the device->host snapshot is synchronous (cheap)
  but serialization/IO overlaps the next steps.
* **straggler accounting**: per-step wall times feed an EWMA; steps slower
  than ``straggler_factor``x the EWMA are counted and surfaced in metrics
  (at cluster scale this signal drives the coded/backup-task path).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs import ArchConfig, ShapeConfig
from repro.data import SyntheticLMData
from repro.models.model_factory import BuiltModel
from repro.optim.adamw import Optimizer
from repro.training.train_state import TrainState, init_train_state
from repro.training.train_step import make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_last: int = 3
    log_every: int = 10
    n_micro: int = 1
    clip_norm: float = 1.0
    straggler_factor: float = 2.0
    seed: int = 0


class Trainer:
    def __init__(self, model: BuiltModel, optimizer: Optimizer,
                 data: SyntheticLMData, tcfg: TrainerConfig,
                 *, train_step: Optional[Callable] = None,
                 log_fn: Callable[[str], None] = print):
        self.model = model
        self.optimizer = optimizer
        self.data = data
        self.tcfg = tcfg
        self.log = log_fn
        step_fn = train_step or make_train_step(
            model, optimizer, n_micro=tcfg.n_micro, clip_norm=tcfg.clip_norm)
        self.train_step = jax.jit(step_fn, donate_argnums=(0,))
        self.ckpt = (AsyncCheckpointer(tcfg.checkpoint_dir, tcfg.keep_last)
                     if tcfg.checkpoint_dir else None)
        self.straggler_steps = 0

    # ---------------- state ------------------------------------------------
    def init_or_restore(self) -> TrainState:
        key = jax.random.PRNGKey(self.tcfg.seed)
        state = init_train_state(self.model.specs, self.optimizer, key)
        d = self.tcfg.checkpoint_dir
        if d and latest_step(d) is not None:
            step, tree = restore_checkpoint(d, state.tree())
            state = TrainState.from_tree(tree)
            self.log(f"[trainer] resumed from checkpoint step {step}")
        return state

    # ---------------- loop -------------------------------------------------
    def run(self, state: Optional[TrainState] = None) -> tuple[TrainState, dict]:
        tcfg = self.tcfg
        if state is None:
            state = self.init_or_restore()
        start = int(jax.device_get(state.step))
        ewma = None
        last_metrics: dict = {}
        for step in range(start, tcfg.total_steps):
            batch = self.data.batch(step)
            t0 = time.perf_counter()
            state, metrics = self.train_step(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > tcfg.straggler_factor * ewma and step > start + 2:
                self.straggler_steps += 1
            last_metrics = {k: float(jax.device_get(v)) for k, v in metrics.items()}
            if (step + 1) % tcfg.log_every == 0 or step + 1 == tcfg.total_steps:
                self.log(f"[trainer] step {step + 1}/{tcfg.total_steps} "
                         f"loss {last_metrics['loss']:.4f} "
                         f"gnorm {last_metrics['grad_norm']:.3f} {dt * 1e3:.0f} ms")
            if self.ckpt and (step + 1) % tcfg.checkpoint_every == 0:
                self.ckpt.save(step + 1, state.tree(),
                               metadata={"loss": last_metrics["loss"]})
        if self.ckpt:
            self.ckpt.save(tcfg.total_steps, state.tree(),
                           metadata=last_metrics)
            self.ckpt.wait()
        last_metrics["straggler_steps"] = self.straggler_steps
        return state, last_metrics
