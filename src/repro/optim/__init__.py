from repro.optim.adamw import AdamWConfig, Optimizer, adamw
from repro.optim.grad_utils import accumulate_grads, clip_by_global_norm, global_norm
from repro.optim.schedules import constant, cosine, linear_warmup, wsd

__all__ = [
    "AdamWConfig", "Optimizer", "adamw",
    "accumulate_grads", "clip_by_global_norm", "global_norm",
    "constant", "cosine", "linear_warmup", "wsd",
]
