"""Learning-rate schedules.

WSD (warmup-stable-decay) is required verbatim by the MiniCPM config
[arXiv:2404.06395]; cosine is the default for everything else.  All
schedules are pure ``step -> lr`` functions of a traced int32 step, so they
live inside the jitted train step.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

__all__ = ["constant", "linear_warmup", "cosine", "wsd", "Schedule"]

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(base: Schedule, warmup_steps: int) -> Schedule:
    def fn(step):
        warm = jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))
        return base(step) * warm

    return fn


def cosine(peak_lr: float, total_steps: int, warmup_steps: int = 0,
           final_frac: float = 0.1) -> Schedule:
    """Linear warmup then cosine decay to ``final_frac * peak_lr``."""

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (step + 1.0) / max(warmup_steps, 1))
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1.0 - final_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.asarray(peak_lr, jnp.float32) * warm * cos

    return fn


def wsd(peak_lr: float, total_steps: int, warmup_steps: int,
        decay_frac: float = 0.1, final_frac: float = 0.01) -> Schedule:
    """Warmup-Stable-Decay (MiniCPM §4): warmup, flat plateau, then a short
    exponential decay over the last ``decay_frac`` of training down to
    ``final_frac * peak_lr``."""

    decay_steps = max(int(total_steps * decay_frac), 1)
    stable_end = total_steps - decay_steps

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (step + 1.0) / max(warmup_steps, 1))
        t = jnp.clip((step - stable_end) / decay_steps, 0.0, 1.0)
        # exponential anneal: lr * final_frac ** t  (t in [0, 1])
        decay = jnp.power(jnp.asarray(final_frac, jnp.float32), t)
        return jnp.asarray(peak_lr, jnp.float32) * warm * decay

    return fn
