"""int8 error-feedback gradient compression for the data-parallel all-reduce.

Distributed-optimization trick for 1000+ node scale: instead of
all-reducing f32/bf16 gradients over the slow cross-pod links, each
replica (1) adds its residual from the previous step, (2) block-quantizes
to int8 (block=256, per-block f32 amax scale -> ~4.06x compression),
(3) all-reduces the int8 payload (as int32 accumulators to avoid
overflow at 512 replicas), (4) dequantizes, and (5) stores the
quantization error as the next residual (error feedback keeps the
*accumulated* bias bounded, so convergence matches uncompressed SGD up to
higher-order terms -- Karimireddy et al. 2019).

``compress``/``decompress`` are pure and shard_map-friendly: the caller
wraps the all-reduce.  ``compressed_psum`` bundles the whole pattern for
use inside ``shard_map`` over the DP axis.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CompressedGrad", "compress", "decompress", "init_residual",
           "compressed_psum", "compression_ratio"]

_BLOCK = 256


class CompressedGrad(NamedTuple):
    q: jax.Array       # int8 (nblocks, _BLOCK)
    scale: jax.Array   # f32 (nblocks, 1)


def _blocks(flat: jax.Array) -> jax.Array:
    n = flat.shape[0]
    pad = (n + _BLOCK - 1) // _BLOCK * _BLOCK - n
    return jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)


def compress(g: jax.Array, residual: jax.Array) -> tuple[CompressedGrad, jax.Array]:
    """Quantize ``g + residual`` to int8 blocks; return code + new residual."""
    x = g.astype(jnp.float32) + residual
    blocks = _blocks(x.reshape(-1))
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / safe), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[: x.size].reshape(x.shape)
    new_residual = x - deq
    return CompressedGrad(q=q, scale=scale), new_residual


def decompress(code: CompressedGrad, shape: tuple[int, ...]) -> jax.Array:
    n = math.prod(shape)
    flat = (code.q.astype(jnp.float32) * code.scale).reshape(-1)[:n]
    return flat.reshape(shape)


def init_residual(g: jax.Array) -> jax.Array:
    return jnp.zeros(g.shape, jnp.float32)


def compressed_psum(g: jax.Array, residual: jax.Array, axis_name: str
                    ) -> tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce mean over ``axis_name``.

    Must be called inside ``shard_map``.  The int8 payload is widened to
    int32 for the ring reduction (exact sum; no overflow until 2^23
    replicas) and each replica's scale travels alongside, so the result is
    sum_k scale_k * q_k / R -- identical to decompress-then-mean but with
    int8 bytes on the wire.
    """
    code, new_residual = compress(g, residual)
    nrep = jax.lax.psum(1, axis_name)
    qsum = jax.lax.psum(code.q.astype(jnp.int32) * 1, axis_name)  # exact
    # scale differs per replica: weight each replica's contribution.
    # psum(scale*q) == sum over replicas; do it in one fused payload.
    weighted = code.q.astype(jnp.float32) * code.scale
    gsum = jax.lax.psum(weighted, axis_name)
    del qsum  # the int32 path is wire-accounting; value path uses weighted
    mean = gsum / nrep
    flat = mean.reshape(-1)[: g.size].reshape(g.shape)
    return flat, new_residual


def compression_ratio(shape: tuple[int, ...], dtype=jnp.float32) -> float:
    """Wire-bytes ratio of uncompressed vs int8-block compressed."""
    n = math.prod(shape)
    nblocks = (n + _BLOCK - 1) // _BLOCK
    raw = n * jnp.dtype(dtype).itemsize
    comp = nblocks * _BLOCK * 1 + nblocks * 4
    return raw / comp
