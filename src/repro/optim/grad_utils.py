"""Gradient utilities: global-norm clipping and microbatch accumulation."""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["global_norm", "clip_by_global_norm", "accumulate_grads"]


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    """Scale the whole gradient pytree so its global norm is <= max_norm."""
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def accumulate_grads(
    loss_fn: Callable[[Any, dict], tuple[jax.Array, dict]],
    params,
    batch: dict,
    n_micro: int,
):
    """Mean loss/grads over ``n_micro`` microbatches via ``lax.scan``.

    ``batch`` leaves have leading dim ``global_batch``; they are reshaped to
    ``(n_micro, global_batch // n_micro, ...)`` and scanned, so peak
    activation memory is one microbatch.  n_micro=1 short-circuits to a
    single grad call.
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    if n_micro == 1:
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def reshape(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])

    micro = jax.tree.map(reshape, batch)

    def body(carry, mb):
        loss_sum, grad_sum = carry
        (loss, metrics), grads = grad_fn(params, mb)
        grad_sum = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32), grad_sum, grads
        )
        return (loss_sum + loss, grad_sum), metrics

    zero_grads = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    (loss_sum, grad_sum), metrics = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zero_grads), micro
    )
    grads = jax.tree.map(lambda g: (g / n_micro), grad_sum)
    last_metrics = jax.tree.map(lambda x: x[-1], metrics)
    return loss_sum / n_micro, last_metrics, grads
