"""MDS-coded gradient aggregation -- straggler-tolerant data parallelism.

Beyond-paper extension (clearly labeled in DESIGN.md §4): the paper's MDS
machinery (core/mds.py) is reapplied to the *gradient sum*, in the spirit
of gradient coding [Tandon et al., cited as ref 14 of the paper].

Setting: the global batch is split into ``m`` partitions; ``N >= m``
workers each compute the gradient of a *coded linear combination* of
partitions (equivalently: a weighted sum of per-partition gradients --
linearity of the gradient in the per-example loss sum makes coding commute
with differentiation, exactly the property the paper exploits for the
DFT).  The aggregator recovers the full-batch gradient sum from ANY ``m``
worker results, so up to ``N - m`` stragglers are tolerated per step with
zero information loss -- compare replication, which needs specific
workers to survive.

Because each worker must *compute* the gradients of every partition it
covers, we use the standard cyclic-support construction: worker k covers
partitions {k, k+1, ..., k+d-1 (mod m)} with d = N - m + 1 ("compute
redundancy" d).  The code below derives the coded weights from the
complex-RS generator restricted to each worker's support via the
closed-form construction of Tandon et al. (B = fractional repetition-free
cyclic code), specialised to real weights.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CyclicGradientCode", "coded_weights"]


def coded_weights(n_workers: int, n_stragglers: int) -> np.ndarray:
    """(N, N) cyclic coding matrix B (Tandon et al., Algorithm 2 ``B_cyc``).

    Row k has support {k, ..., k + s} (mod N), s = n_stragglers.  Pick a
    random H in R^{s x N} with H @ 1 = 0; choose each row b_k in null(H)
    with the prescribed support (solve the s x s system pinning
    b_k[k] = 1).  Then every row lies in the (N-s)-dim null(H), which
    contains the all-ones vector, and any N-s rows span it generically --
    so EVERY (N-s)-subset of workers can linearly combine to 1^T and
    recover the full gradient sum.
    """
    n, s = n_workers, n_stragglers
    if s == 0:
        return np.eye(n)
    rng = np.random.default_rng(0)
    # H: s x n random Gaussian with columns summing to zero per row
    H = rng.standard_normal((s, n))
    H[:, -1] = -H[:, :-1].sum(axis=1)          # H @ 1 = 0
    B = np.zeros((n, n))
    for k in range(n):
        support = [(k + j) % n for j in range(s + 1)]
        rest = support[1:]
        # b[k]=1; solve H[:, rest] y = -H[:, k]  (s x s, generically invertible)
        y = np.linalg.solve(H[:, rest], -H[:, k])
        B[k, k] = 1.0
        B[k, rest] = y
    return B


@dataclasses.dataclass(frozen=True)
class CyclicGradientCode:
    """Coded gradient aggregation plan: N workers, tolerate s stragglers."""

    n_workers: int
    n_stragglers: int

    def __post_init__(self):
        if not 0 <= self.n_stragglers < self.n_workers:
            raise ValueError("need 0 <= s < N")

    @property
    def recovery_threshold(self) -> int:
        return self.n_workers - self.n_stragglers

    @property
    def matrix(self) -> np.ndarray:
        return coded_weights(self.n_workers, self.n_stragglers)

    def worker_partitions(self, k: int) -> list[int]:
        """Partitions worker k must run (its coded support)."""
        d = self.n_stragglers + 1
        return [(k + j) % self.n_workers for j in range(d)]

    def encode_worker_grad(self, k: int, partition_grads: list) -> jax.Array:
        """Worker k's message: sum_j B[k,j] * g_j over its support."""
        B = self.matrix
        out = None
        for j in self.worker_partitions(k):
            term = jax.tree.map(lambda g: B[k, j] * g.astype(jnp.float32),
                                partition_grads[j])
            out = term if out is None else jax.tree.map(jnp.add, out, term)
        return out

    def decode_vector(self, subset: np.ndarray) -> np.ndarray:
        """a with a^T B[subset] = 1^T: the aggregation weights for ``subset``."""
        B = self.matrix[np.asarray(subset)]
        ones = np.ones(self.n_workers)
        a, res, rank, _ = np.linalg.lstsq(B.T, ones, rcond=None)
        if res.size and res[0] > 1e-12 * self.n_workers:
            raise np.linalg.LinAlgError(
                f"subset {subset} not decodable (residual {res[0]:.2e})")
        # verify exactly (lstsq silently accepts rank-deficient fits)
        if not np.allclose(a @ B, ones, atol=1e-6):
            raise np.linalg.LinAlgError(f"subset {subset} not decodable")
        return a

    def decode(self, subset: np.ndarray, worker_msgs: list):
        """Full-batch gradient sum from any ``recovery_threshold`` messages.

        ``worker_msgs[i]`` is the message of worker ``subset[i]``.
        """
        a = self.decode_vector(subset)
        out = None
        for w, msg in zip(a, worker_msgs):
            term = jax.tree.map(lambda g: w * g, msg)
            out = term if out is None else jax.tree.map(jnp.add, out, term)
        return out
