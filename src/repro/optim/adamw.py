"""Self-contained AdamW with optional int8 block-quantized moments.

No optax in this environment, so the optimizer is a (init, update) pair
over arbitrary param pytrees.  The int8 mode stores both Adam moments as
int8 blocks with one f32 scale per block (block=256 on the flattened
tensor), cutting optimizer state from 8 to ~2.03 bytes/param -- this is
what lets dbrx-132b / llama4-400b train_4k fit 256 chips x 16 GB.

Quantization is *stochastic-free deterministic* (round-to-nearest) with the
second moment stored as sqrt(v) to tame its dynamic range; tests bound the
drift vs exact AdamW.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim.schedules import Schedule, constant

__all__ = ["AdamWConfig", "Optimizer", "adamw", "QuantMoment",
           "quantize_moment", "dequantize_moment"]

_BLOCK = 256


class QuantMoment(NamedTuple):
    """Param-shaped int8 payload + per-block f32 scales.

    ``q`` has EXACTLY the parameter's shape (so it inherits the parameter's
    sharding with zero resharding -- a flat block layout forces GSPMD to
    all-gather giant moments through reshapes; on llama4-400b that
    materialized 64 GB unsharded expert moments per step).  Blocks run
    along the last axis with size = largest power-of-two divisor <= 256;
    ``scale`` has shape ``lead + (last/block,)``.
    """

    q: jax.Array          # int8, param shape
    scale: jax.Array      # f32, lead + (nblk,)


def moment_block(last: int) -> int:
    b = 1
    while b < _BLOCK and last % (b * 2) == 0:
        b *= 2
    return b


def quantize_moment(x: jax.Array) -> QuantMoment:
    x = x.astype(jnp.float32)
    if x.ndim == 0:
        x = x.reshape(1)
    last = x.shape[-1]
    b = moment_block(last)
    lead = x.shape[:-1]
    xb = x.reshape(lead + (last // b, b))
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(xb / safe[..., None]), -127, 127).astype(jnp.int8)
    return QuantMoment(q=q.reshape(x.shape), scale=scale)


def dequantize_moment(qm: QuantMoment, shape: tuple[int, ...]) -> jax.Array:
    work = shape if shape else (1,)
    last = work[-1]
    nblk = qm.scale.shape[-1]
    b = last // nblk
    xb = qm.q.reshape(work[:-1] + (nblk, b)).astype(jnp.float32)
    return (xb * qm.scale[..., None]).reshape(shape)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    quantized_state: bool = False   # int8 block-quantized moments


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """(init, update) pair.  ``update`` returns (new_params, new_state)."""

    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    config: AdamWConfig


def _leaf_init(p: jax.Array, quantized: bool):
    if quantized:
        z = jnp.zeros(p.shape, jnp.float32)
        return {"m": quantize_moment(z), "v": quantize_moment(z)}
    return {"m": jnp.zeros(p.shape, jnp.float32),
            "v": jnp.zeros(p.shape, jnp.float32)}


def _leaf_update(p, g, st, lr, cfg: AdamWConfig, t):
    g = g.astype(jnp.float32)
    pf = p.astype(jnp.float32)
    if cfg.quantized_state:
        m = dequantize_moment(st["m"], p.shape)
        # v is stored as sqrt(v) for dynamic range; square on load.
        v = jnp.square(dequantize_moment(st["v"], p.shape))
    else:
        m, v = st["m"], st["v"]
    m = cfg.b1 * m + (1.0 - cfg.b1) * g
    v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
    # bias correction
    mhat = m / (1.0 - cfg.b1 ** t)
    vhat = v / (1.0 - cfg.b2 ** t)
    upd = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf
    new_p = (pf - lr * upd).astype(p.dtype)
    if cfg.quantized_state:
        new_st = {"m": quantize_moment(m), "v": quantize_moment(jnp.sqrt(v))}
    else:
        new_st = {"m": m, "v": v}
    return new_p, new_st


def adamw(lr: Schedule | float = 1e-3, config: Optional[AdamWConfig] = None) -> Optimizer:
    cfg = config or AdamWConfig()
    lr_fn = lr if callable(lr) else constant(lr)

    def init(params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(lambda p: _leaf_init(p, cfg.quantized_state), params),
        }

    def update(params, grads, state, step=None):
        t = state["count"] + 1
        lr_t = lr_fn(t if step is None else step)
        tf = t.astype(jnp.float32)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["mu"])
        out = [_leaf_update(p, g, s, lr_t, cfg, tf)
               for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_mu = treedef.unflatten([o[1] for o in out])
        return new_params, {"count": t, "mu": new_mu}

    return Optimizer(init=init, update=update, config=cfg)
