"""Deterministic synthetic data pipeline (shard-aware).

Real deployments swap in a tokenized corpus reader behind the same
interface; what the framework needs from the pipeline layer is (1) a
deterministic step->batch map so checkpoint/restart resumes mid-epoch
without data loss or duplication, (2) host-sharded reads so each process
only materializes its slice, (3) the modality stubs for the audio/vlm
architectures (precomputed frame/patch embeddings per the assignment).

Tokens are drawn from a counter-based generator (threefry on (step, index))
so ``batch(step)`` is random-access -- no iterator state to checkpoint.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig, ShapeConfig

__all__ = ["SyntheticLMData", "make_pipeline"]


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    """Random-access synthetic LM batches: ``tokens``/``labels`` (+stubs)."""

    cfg: ArchConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    # host sharding: this process holds rows [row_start, row_start+rows)
    row_start: int = 0
    rows: Optional[int] = None

    @property
    def local_rows(self) -> int:
        return self.rows if self.rows is not None else self.global_batch

    def _tokens(self, step: int, rows: int, offset: int) -> np.ndarray:
        # counter-based AND row-addressed: row r of the GLOBAL batch is a
        # pure function of (seed, step, r), so any host-sharding of rows
        # yields exactly the rows the single-host run would produce --
        # elasticity can re-partition mid-run without changing the data.
        out = np.empty((rows, self.seq_len + 1), np.int32)
        for i in range(rows):
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, offset + i]))
            out[i] = rng.integers(0, self.cfg.vocab_size,
                                  size=self.seq_len + 1, dtype=np.int64)
        return out

    def batch(self, step: int) -> dict:
        """Batch for ``step`` (local slice only)."""
        cfg = self.cfg
        toks = self._tokens(step, self.local_rows, self.row_start)
        out = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
        if cfg.family == "encdec":
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed + 7, step, self.row_start]))
            out["frames"] = jnp.asarray(
                rng.standard_normal((self.local_rows, self.seq_len, cfg.d_model))
                .astype(np.float32), dtype=jnp.bfloat16)
        if cfg.family == "vlm" and cfg.num_prefix_tokens:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed + 13, step, self.row_start]))
            out["patches"] = jnp.asarray(
                rng.standard_normal(
                    (self.local_rows, cfg.num_prefix_tokens, cfg.d_model))
                .astype(np.float32), dtype=jnp.bfloat16)
            # backbone sees [patches ; tokens]: trim text so total = seq_len
            text = self.seq_len - cfg.num_prefix_tokens
            out["tokens"] = out["tokens"][:, :text]
            out["labels"] = out["labels"][:, :text]
        return out


def make_pipeline(cfg: ArchConfig, shape: ShapeConfig, *, seed: int = 0,
                  process_index: int = 0, process_count: int = 1,
                  global_batch: Optional[int] = None) -> SyntheticLMData:
    """Host-sharded pipeline: each process reads its contiguous row block."""
    gb = global_batch if global_batch is not None else shape.global_batch
    assert gb % process_count == 0, (gb, process_count)
    rows = gb // process_count
    return SyntheticLMData(
        cfg=cfg, seq_len=shape.seq_len, global_batch=gb, seed=seed,
        row_start=process_index * rows, rows=rows,
    )
