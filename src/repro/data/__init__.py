from repro.data.pipeline import SyntheticLMData, make_pipeline

__all__ = ["SyntheticLMData", "make_pipeline"]
