"""Parameter descriptor trees: single source of truth for shape, init,
logical sharding axes and dtype of every parameter.

``init_params`` materializes values; ``param_pspecs`` materializes the
PartitionSpec tree the launcher feeds to ``jax.jit(in_shardings=...)``.
Keeping both derived from one descriptor tree means the sharding plan can
never drift from the model definition.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.distributed.sharding import AxisRules, logical_spec

__all__ = ["Spec", "init_params", "param_pspecs", "count_params", "tree_bytes"]


@dataclasses.dataclass(frozen=True)
class Spec:
    """Descriptor for one parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]  # logical axis names, len == len(shape)
    init: str = "normal"             # normal | zeros | ones | embed
    fan_in: Optional[int] = None     # for 1/sqrt(fan_in) scaling
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_one(spec: Spec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    fan_in = spec.fan_in
    if fan_in is None:
        fan_in = spec.shape[0] if len(spec.shape) > 1 else spec.shape[-1]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    if spec.init == "embed":
        scale = 1.0
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(spec.dtype)


def init_params(tree, key: jax.Array):
    """Materialize a pytree of Specs into a pytree of arrays."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=lambda x: isinstance(x, Spec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(tree):
    """ShapeDtypeStructs for lowering without allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        tree,
        is_leaf=lambda x: isinstance(x, Spec),
    )


def param_pspecs(tree, rules: Optional[AxisRules] = None):
    """PartitionSpec pytree mirroring the descriptor tree."""
    return jax.tree.map(
        lambda s: logical_spec(s.axes, rules),
        tree,
        is_leaf=lambda x: isinstance(x, Spec),
    )


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, Spec))
    return sum(math.prod(s.shape) for s in leaves)


def tree_bytes(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, Spec))
    return sum(math.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves)
