"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv frontend is a STUB per the assignment: inputs are precomputed
frame embeddings (B, T, d_model).  Pre-LN transformer with LayerNorm, GELU
MLP, sinusoidal positions (deviation: decoder also uses sinusoidal instead
of learned positions -- noted in configs/whisper_medium.py), tied unembed.

Decode caches: self-attention KV (grows with generated tokens) plus
per-layer cross-attention K/V computed once from the encoder output.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.sharding import lshard
from repro.models.attention import chunked_attention
from repro.models.layers import layer_norm, mlp_apply, sinusoidal_positions
from repro.models.losses import sharded_xent_loss
from repro.models.params import Spec
from repro.models.transformer import _attn_specs, _mlp_specs, stack_specs

__all__ = [
    "encdec_specs",
    "encdec_loss",
    "encdec_prefill",
    "encdec_decode_step",
    "init_encdec_cache",
    "encode",
]


def _ln(cfg) -> dict:
    d = cfg.d_model
    return {"w": Spec((d,), (None,), init="ones", dtype=jnp.float32),
            "b": Spec((d,), (None,), init="zeros", dtype=jnp.float32)}


def _mlp_bias_specs(cfg, dtype) -> dict:
    sp = _mlp_specs(cfg, dtype)
    sp["bi"] = Spec((cfg.d_ff,), ("p_mlp",), init="zeros", dtype=dtype)
    sp["bo"] = Spec((cfg.d_model,), (None,), init="zeros", dtype=dtype)
    return sp


def _enc_layer(cfg, dtype) -> dict:
    return {
        "ln1": _ln(cfg),
        "attn": _attn_specs(cfg, dtype),
        "ln2": _ln(cfg),
        "mlp": _mlp_bias_specs(cfg, dtype),
    }


def _dec_layer(cfg, dtype) -> dict:
    return {
        "ln1": _ln(cfg),
        "self_attn": _attn_specs(cfg, dtype),
        "ln_x": _ln(cfg),
        "cross_attn": _attn_specs(cfg, dtype),
        "ln2": _ln(cfg),
        "mlp": _mlp_bias_specs(cfg, dtype),
    }


def encdec_specs(cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    return {
        "embed": Spec((cfg.vocab_size, cfg.d_model), ("p_vocab", "p_fsdp"),
                      init="embed", dtype=dtype),
        "enc_ln_post": _ln(cfg),
        "dec_ln_post": _ln(cfg),
        "enc_layers": stack_specs(_enc_layer(cfg, dtype), cfg.encoder_layers),
        "dec_layers": stack_specs(_dec_layer(cfg, dtype), cfg.n_layers),
    }


def _mha(p, cfg, xq, xkv, *, causal, cache=None, step=None, mode="train"):
    """Attention helper for enc/dec (no RoPE; absolute sinusoidal positions
    are added to the inputs)."""
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"])
    q = lshard(q, "batch", "seq", "heads", "head_dim")
    if xkv is not None:
        k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"])
        k = lshard(k, "batch", "seq", "kv_heads", "head_dim")
        v = lshard(v, "batch", "seq", "kv_heads", "head_dim")
    else:  # cached cross-attention
        k, v = cache["k"], cache["v"]

    if mode == "decode" and causal:
        # self-attention with linear cache
        c_len = cache["k"].shape[1]
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), step, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), step, axis=1)
        out = chunked_attention(
            q, kc, vc, causal=True,
            q_positions=jnp.reshape(step, (1,)),
            kv_positions=jnp.arange(c_len),
            chunk=2048,
        )
        new_cache = {"k": kc, "v": vc}
    else:
        out = chunked_attention(q, k, v, causal=causal, chunk=1024)
        new_cache = None
        if mode == "prefill" and causal and cache is not None:
            c_len = cache["k"].shape[1]
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
            new_cache = {"k": kc, "v": vc}
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return lshard(y, "batch", "seq", "embed"), new_cache


def encode(params, cfg, frames: jax.Array) -> jax.Array:
    """Encoder over precomputed frame embeddings (B, T, D)."""
    t = frames.shape[1]
    x = frames + sinusoidal_positions(t, cfg.d_model).astype(frames.dtype)[None]
    x = lshard(x, "batch", "seq", "embed")

    def step(xc, lp):
        h, _ = _mha(lp["attn"], cfg, layer_norm(xc, lp["ln1"]["w"], lp["ln1"]["b"]),
                    layer_norm(xc, lp["ln1"]["w"], lp["ln1"]["b"]), causal=False)
        xc = xc + h
        xc = xc + mlp_apply(layer_norm(xc, lp["ln2"]["w"], lp["ln2"]["b"]),
                            lp["mlp"], "gelu")
        return xc, None

    if cfg.remat != "none":
        step = jax.checkpoint(step)
    x, _ = jax.lax.scan(step, x, params["enc_layers"])
    return layer_norm(x, params["enc_ln_post"]["w"], params["enc_ln_post"]["b"])


def _decoder(params, cfg, tok_emb, enc_out, *, mode, cache=None, step=None):
    t = tok_emb.shape[1]
    if mode == "decode":
        pos = sinusoidal_positions(cache["max_len"].shape[0], cfg.d_model)
        pos_t = jax.lax.dynamic_slice_in_dim(pos, step, 1, axis=0)
        x = tok_emb + pos_t.astype(tok_emb.dtype)[None]
    else:
        x = tok_emb + sinusoidal_positions(t, cfg.d_model).astype(tok_emb.dtype)[None]
    x = lshard(x, "batch", "seq", "embed")

    def step_fn(xc, xs):
        lp, lc = xs
        xn1 = layer_norm(xc, lp["ln1"]["w"], lp["ln1"]["b"])
        h, new_self = _mha(
            lp["self_attn"], cfg, xn1, xn1,
            causal=True, mode=mode,
            cache=None if lc is None else lc["self"], step=step,
        )
        xc = xc + h
        xn = layer_norm(xc, lp["ln_x"]["w"], lp["ln_x"]["b"])
        if mode == "train":
            h2, _ = _mha(lp["cross_attn"], cfg, xn, enc_out, causal=False)
            new_cross = None
        elif mode == "prefill":
            # also build the cross KV cache from the encoder output
            k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"])
            h2, _ = _mha(lp["cross_attn"], cfg, xn, enc_out, causal=False)
            new_cross = {"k": k.astype(lc["cross"]["k"].dtype),
                         "v": v.astype(lc["cross"]["v"].dtype)}
        else:
            h2, _ = _mha(lp["cross_attn"], cfg, xn, None, causal=False,
                         cache=lc["cross"], mode="cached")
            new_cross = lc["cross"]
        xc = xc + h2
        xc = xc + mlp_apply(layer_norm(xc, lp["ln2"]["w"], lp["ln2"]["b"]),
                            lp["mlp"], "gelu")
        new_lc = None
        if new_self is not None or mode == "decode":
            new_lc = {"self": new_self, "cross": new_cross}
        return xc, new_lc

    if cfg.remat != "none":
        step_fn = jax.checkpoint(step_fn)

    if cache is None:
        x, _ = jax.lax.scan(lambda c, lp: step_fn(c, (lp, None)), x, params["dec_layers"])
        new_layers = None
    else:
        x, new_layers = jax.lax.scan(step_fn, x, (params["dec_layers"], cache["layers"]))
    x = layer_norm(x, params["dec_ln_post"]["w"], params["dec_ln_post"]["b"])
    return x, new_layers


def init_encdec_cache(cfg: ArchConfig, batch: int, cache_len: int,
                      dtype=jnp.bfloat16) -> dict:
    ell, kh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    return {
        "max_len": jnp.zeros((cache_len,), jnp.int8),  # length marker only
        "layers": {
            "self": {
                "k": jnp.zeros((ell, batch, cache_len, kh, hd), dtype),
                "v": jnp.zeros((ell, batch, cache_len, kh, hd), dtype),
            },
            "cross": {
                "k": jnp.zeros((ell, batch, cache_len, kh, hd), dtype),
                "v": jnp.zeros((ell, batch, cache_len, kh, hd), dtype),
            },
        },
    }


def _tok_embed(params, cfg, tokens):
    e = jnp.take(params["embed"], tokens, axis=0)
    return lshard(e, "batch", "seq", "embed")


def _head(params, cfg, x):
    logits = jnp.einsum("btd,vd->btv", x.astype(jnp.bfloat16),
                        params["embed"].astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    return lshard(logits, "batch", None, "vocab")


def encdec_loss(params, cfg, batch):
    enc_out = encode(params, cfg, batch["frames"])
    x, _ = _decoder(params, cfg, _tok_embed(params, cfg, batch["tokens"]),
                    enc_out, mode="train")
    loss_sum, count = sharded_xent_loss(
        x, params["embed"].T, batch["labels"], mask=batch.get("mask")
    )
    loss = loss_sum / jnp.maximum(count, 1.0)
    return loss, {"xent": loss}


def encdec_prefill(params, cfg, batch, cache):
    enc_out = encode(params, cfg, batch["frames"])
    x, new_layers = _decoder(params, cfg, _tok_embed(params, cfg, batch["tokens"]),
                             enc_out, mode="prefill", cache=cache)
    new_cache = dict(cache, layers=new_layers)
    return _head(params, cfg, x[:, -1:]), new_cache


def encdec_decode_step(params, cfg, cache, batch, step):
    x, new_layers = _decoder(params, cfg, _tok_embed(params, cfg, batch["tokens"]),
                             None, mode="decode", cache=cache, step=step)
    new_cache = dict(cache, layers=new_layers)
    return _head(params, cfg, x), new_cache
