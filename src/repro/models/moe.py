"""Mixture-of-Experts FFN with group-local sort-based capacity dispatch.

GShard-style one-hot dispatch tensors of shape (tokens, E, C) are ruinous at
1M tokens x 128 experts, so we use the sort-based formulation (MegaBlocks
lineage): flatten token->expert assignments, argsort by expert, compute each
assignment's position within its expert via a searchsorted offset, scatter
into a capacity buffer (overflow drops, like capacity-factor routing), run
the expert FFNs as one batched einsum with E sharded over 'model' (expert
parallelism), and combine back with a segment-sum.

**Dispatch locality** (the part that matters at 512 chips): all routing,
sorting and scattering happens within a leading *group* axis sized to the
data-parallel degree -- tokens are viewed as (G, T/G, d) with G sharded over
the batch axes, so argsort/scatter/gather never cross a data shard.  The
only cross-device movement is the (G, E, C, d) capacity buffer resharding
from group-major (data) to expert-major (model): exactly one all-to-all
each way, which is the textbook MoE communication pattern.  (The first
implementation sorted the GLOBAL token axis; GSPMD dutifully all-gathered
every token to every chip -- 11 TB of wire and 482 GB of temp per chip on
llama4-400b train_4k.  The group axis removes that by construction.)

Router styles: 'softmax' (DBRX: softmax over all experts, renormalized
top-k) and 'sigmoid' (Llama-4: sigmoid gate on the top-1 logit).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import MoESettings
from repro.distributed.sharding import current_mesh, current_rules, lshard
from repro.models.params import Spec

__all__ = ["moe_layer_specs", "moe_ffn", "moe_capacity"]


def moe_capacity(n_tokens: int, moe: MoESettings) -> int:
    cap = int(math.ceil(n_tokens * moe.top_k * moe.capacity_factor / moe.num_experts))
    return max(8, min(cap, n_tokens))


def _dp_groups(n_tokens: int) -> int:
    """Dispatch-group count = data-parallel degree of the token axis."""
    mesh, rules = current_mesh(), current_rules()
    if mesh is None or rules is None:
        return 1
    entry = rules.get("tokens")
    if entry is None:
        return 1
    names = entry if isinstance(entry, (tuple, list)) else (entry,)
    g = 1
    for a in names:
        g *= mesh.shape[a]
    return g if (g > 1 and n_tokens % g == 0) else 1


def moe_layer_specs(d_model: int, moe: MoESettings, dtype=jnp.bfloat16) -> dict:
    e, f = moe.num_experts, moe.d_ff_expert
    sp = {
        "router": Spec((d_model, e), ("p_fsdp", "p_none"), dtype=jnp.float32),
        # expert weights: EP over "model" via p_experts; the expert-internal ff
        # axis must NOT also map to "model" (duplicate-axis error), so it uses
        # its own logical axis (replicated; each device holds whole experts)
        "wi": Spec((e, d_model, f), ("p_experts", "p_fsdp", "p_expert_mlp"), dtype=dtype),
        "wg": Spec((e, d_model, f), ("p_experts", "p_fsdp", "p_expert_mlp"), dtype=dtype),
        "wo": Spec((e, f, d_model), ("p_experts", "p_expert_mlp", "p_fsdp"), dtype=dtype),
    }
    if moe.num_shared_experts:
        fs = f * moe.num_shared_experts
        sp["shared_wi"] = Spec((d_model, fs), ("p_fsdp", "p_mlp"), dtype=dtype)
        sp["shared_wg"] = Spec((d_model, fs), ("p_fsdp", "p_mlp"), dtype=dtype)
        sp["shared_wo"] = Spec((fs, d_model), ("p_mlp", "p_fsdp"), dtype=dtype)
    return sp


def moe_ffn(
    x: jax.Array,               # (B, S, D)
    p: dict,
    moe: MoESettings,
    *,
    router_style: str = "softmax",
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B, S, D), load-balance aux loss)."""
    b, s, d = x.shape
    t = b * s
    e, k = moe.num_experts, moe.top_k
    g = _dp_groups(t)
    tl = t // g                     # tokens per dispatch group (one DP shard)
    cap = moe_capacity(tl, moe)

    xf = x.reshape(g, tl, d)
    xf = lshard(xf, "tokens", None, "embed")
    logits = jnp.einsum("gtd,de->gte", xf.astype(jnp.float32), p["router"])

    if router_style == "sigmoid":
        top_vals, top_idx = jax.lax.top_k(logits, k)
        gates = jax.nn.sigmoid(top_vals)
        probs = jax.nn.softmax(logits, axis=-1)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        top_vals, top_idx = jax.lax.top_k(probs, k)
        gates = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (Switch-style, over ALL tokens) -------------
    assign_onehot = jax.nn.one_hot(top_idx[..., 0], e, dtype=jnp.float32)
    frac_tokens = assign_onehot.mean(axis=(0, 1))
    frac_prob = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_prob)

    # ---- group-local sort-based dispatch -----------------------------------
    flat_e = top_idx.reshape(g, tl * k)
    flat_g = gates.reshape(g, tl * k).astype(x.dtype)
    order = jnp.argsort(flat_e, axis=-1)
    se = jnp.take_along_axis(flat_e, order, axis=-1)      # sorted expert ids
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e)))(se)
    pos = jnp.arange(tl * k)[None, :] - jnp.take_along_axis(starts, se, axis=-1)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, 0)
    token = order // k                                    # (g, tl*k) local idx

    gathered = jnp.where(
        keep[..., None],
        jnp.take_along_axis(xf, token[..., None], axis=1),
        jnp.zeros((), x.dtype))
    # Shard d over "model" BEFORE the scatter (free split: xf's d is
    # replicated), so the scatter writes a locally-owned buffer.  Scattering
    # straight into an expert-sharded buffer makes GSPMD emit full-buffer
    # mask + all-reduce instead of an all-to-all (u32/f32[tl*k, d]
    # all-reduces, 57% of this cell's wire; §Perf cell B' iteration 3).
    gathered = lshard(gathered, "tokens", None, "mlp")

    # batched (vmap) scatter/gather everywhere: the batching dim gives
    # GSPMD license to keep each group's dispatch on its own data shard
    # (the unbatched fancy-index form all-reduced 12.9 TB per MoE layer).
    def _scatter_one(se_g, pos_g, gath_g):
        buf_g = jnp.zeros((e, cap, d), x.dtype)
        return buf_g.at[se_g, pos_g].set(gath_g, mode="drop")

    buf = jax.vmap(_scatter_one)(se, pos_c, gathered)
    buf = lshard(buf, "tokens", None, None, "mlp")   # local scatter layout
    # e <-> d axis swap: THE dispatch all-to-all between token-major (d
    # sharded) and expert-major (e sharded) layouts
    buf = lshard(buf, "tokens", "experts", None, "embed")

    # ---- expert FFN (E sharded over 'model') -------------------------------
    h = jnp.einsum("gecd,edf->gecf", buf, p["wi"])
    hg = jnp.einsum("gecd,edf->gecf", buf, p["wg"])
    h = lshard(h, "tokens", "experts", None, "expert_mlp")
    y = jnp.einsum("gecf,efd->gecd", jax.nn.silu(hg) * h, p["wo"])
    y = lshard(y, "tokens", "experts", None, "embed")

    # ---- combine (group-local gather + segment sum) -------------------------
    # e <-> d axis swap back (the combine all-to-all): with e local and d
    # model-sharded, the gather/scatter below never leave the chip
    y = lshard(y, "tokens", None, None, "mlp")
    vals = jax.vmap(lambda y_g, se_g, pos_g: y_g[se_g, pos_g])(y, se, pos_c)
    vals = lshard(vals, "tokens", None, "mlp")
    w = jnp.take_along_axis(flat_g, order, axis=-1) * keep.astype(x.dtype)
    vals = vals * w[..., None]                            # (g, tl*k, d)
    out = jax.vmap(
        lambda tok_g, val_g: jnp.zeros((tl, d), x.dtype).at[tok_g].add(val_g)
    )(token, vals)
    out = lshard(out, "tokens", None, "mlp")
    # back to the replicated-d residual layout: one all-gather of (tl, d)
    out = lshard(out, "tokens", None, "embed")

    # ---- shared expert (dense, always-on) ----------------------------------
    if "shared_wi" in p:
        hs = jnp.einsum("gtd,df->gtf", xf, p["shared_wi"])
        gs = jnp.einsum("gtd,df->gtf", xf, p["shared_wg"])
        hs = lshard(hs, "tokens", None, "mlp")
        gs = lshard(gs, "tokens", None, "mlp")
        out = out + jnp.einsum("gtf,fd->gtd", jax.nn.silu(gs) * hs, p["shared_wo"])

    return out.reshape(b, s, d), aux
