"""Decoder-only transformer LM (dense / MoE / prefix-LM VLM families).

Design points that matter at 512-device scale:

* layers are scanned over *stacked* params (HLO size independent of depth --
  critical for GSPMD compile times on the production mesh);
* heterogeneous depth patterns (Llama-4's alternating dense/MoE) scan over
  "superblocks" whose slots hold one stacked param tree each;
* attention is the chunked flash-style implementation (O(S*chunk) memory);
* losses never materialize unsharded logits (models/losses.py);
* KV caches support full, sliding-window (ring) and int8-quantized layouts.

Everything is a pure function over an explicit param pytree built from
``Spec`` descriptors (models/params.py) -- one source of truth for init and
for the sharding plan.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.sharding import lshard
from repro.models import moe as moe_lib
from repro.models.attention import (
    QuantKV,
    chunked_attention,
    quantize_kv,
    ring_positions,
)
from repro.models.layers import apply_rotary, layer_norm, mlp_apply, rms_norm, rotary_cos_sin
from repro.models.losses import sharded_xent_loss
from repro.models.params import Spec

__all__ = [
    "transformer_specs",
    "embed_tokens",
    "decoder_hidden",
    "unembed_matrix",
    "lm_loss",
    "lm_prefill",
    "lm_decode_step",
    "init_kv_cache",
    "attn_apply",
    "norm_apply",
    "stack_specs",
    "ATTN_CHUNK",
]

ATTN_CHUNK = 1024


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------
def _attn_specs(cfg: ArchConfig, dtype) -> dict:
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    sp = {
        "wq": Spec((d, h, hd), ("p_fsdp", "p_heads", None), dtype=dtype, fan_in=d),
        "wk": Spec((d, kh, hd), ("p_fsdp", "p_kv", None), dtype=dtype, fan_in=d),
        "wv": Spec((d, kh, hd), ("p_fsdp", "p_kv", None), dtype=dtype, fan_in=d),
        "wo": Spec((h, hd, d), ("p_heads", None, "p_fsdp"), dtype=dtype, fan_in=h * hd),
    }
    if cfg.qkv_bias:
        sp["bq"] = Spec((h, hd), ("p_heads", None), init="zeros", dtype=dtype)
        sp["bk"] = Spec((kh, hd), ("p_kv", None), init="zeros", dtype=dtype)
        sp["bv"] = Spec((kh, hd), ("p_kv", None), init="zeros", dtype=dtype)
    return sp


def _mlp_specs(cfg: ArchConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_variant in ("swiglu", "geglu"):
        return {
            "wi": Spec((d, f), ("p_fsdp", "p_mlp"), dtype=dtype, fan_in=d),
            "wg": Spec((d, f), ("p_fsdp", "p_mlp"), dtype=dtype, fan_in=d),
            "wo": Spec((f, d), ("p_mlp", "p_fsdp"), dtype=dtype, fan_in=f),
        }
    return {
        "wi": Spec((d, f), ("p_fsdp", "p_mlp"), dtype=dtype, fan_in=d),
        "wo": Spec((f, d), ("p_mlp", "p_fsdp"), dtype=dtype, fan_in=f),
    }


def _norm_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    if cfg.norm == "ln":
        return {"w": Spec((d,), (None,), init="ones", dtype=jnp.float32),
                "b": Spec((d,), (None,), init="zeros", dtype=jnp.float32)}
    return {"w": Spec((d,), (None,), init="zeros", dtype=jnp.float32)}


def norm_apply(p: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if cfg.norm == "ln":
        return layer_norm(x, p["w"], p["b"])
    return rms_norm(x, p["w"], zero_centered=True)


def _layer_specs(cfg: ArchConfig, is_moe: bool, dtype) -> dict:
    sp = {
        "ln1": _norm_specs(cfg),
        "attn": _attn_specs(cfg, dtype),
        "ln2": _norm_specs(cfg),
    }
    if is_moe:
        sp["moe"] = moe_lib.moe_layer_specs(cfg.d_model, cfg.moe, dtype)
    else:
        sp["mlp"] = _mlp_specs(cfg, dtype)
    return sp


def stack_specs(tree, n: int):
    """Add a leading stacked-layers axis to every Spec in the tree."""
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, ("layers",) + s.axes, s.init, s.fan_in, s.dtype),
        tree,
        is_leaf=lambda x: isinstance(x, Spec),
    )


def _block_structure(cfg: ArchConfig) -> tuple[tuple[bool, ...], int]:
    """(slot_is_moe pattern, n_repeats) for superblock scanning."""
    flags = cfg.moe_layer_flags
    if cfg.moe is None:
        return (False,), cfg.n_layers
    step = cfg.moe.interleave_step
    pattern = flags[:step]
    assert flags == pattern * (cfg.n_layers // step), "non-periodic MoE pattern"
    return pattern, cfg.n_layers // step


def transformer_specs(cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    pattern, repeats = _block_structure(cfg)
    sp: dict[str, Any] = {
        "embed": Spec((cfg.vocab_size, cfg.d_model), ("p_vocab", "p_fsdp"),
                      init="embed", dtype=dtype),
        "final_norm": _norm_specs(cfg),
        "blocks": [
            stack_specs(_layer_specs(cfg, is_moe, dtype), repeats)
            for is_moe in pattern
        ],
    }
    if not cfg.tie_embeddings:
        sp["unembed"] = Spec((cfg.d_model, cfg.vocab_size), ("p_fsdp", "p_vocab"),
                             dtype=dtype, fan_in=cfg.d_model)
    return sp


# --------------------------------------------------------------------------
# attention with cache handling
# --------------------------------------------------------------------------
def _write_full_cache(cache_kv, new, start):
    """Insert (B, S, KH, hd) at position ``start`` along the seq axis."""
    if isinstance(cache_kv, QuantKV):
        qn = quantize_kv(new)
        return QuantKV(
            q=jax.lax.dynamic_update_slice_in_dim(cache_kv.q, qn.q, start, axis=1),
            scale=jax.lax.dynamic_update_slice_in_dim(cache_kv.scale, qn.scale, start, axis=1),
        )
    return jax.lax.dynamic_update_slice_in_dim(cache_kv, new.astype(cache_kv.dtype), start, axis=1)


def _scatter_cache(cache_kv, new, idx):
    """Scatter (B, S, KH, hd) rows into slots ``idx`` (ring prefill)."""
    if isinstance(cache_kv, QuantKV):
        qn = quantize_kv(new)
        return QuantKV(
            q=cache_kv.q.at[:, idx].set(qn.q),
            scale=cache_kv.scale.at[:, idx].set(qn.scale),
        )
    return cache_kv.at[:, idx].set(new.astype(cache_kv.dtype))


def attn_apply(
    p: dict,
    cfg: ArchConfig,
    x: jax.Array,
    cos: jax.Array,
    sin: jax.Array,
    *,
    mode: str,                      # train | prefill | decode
    cache: Optional[dict] = None,   # {"k": ..., "v": ...} for this layer
    step: Optional[jax.Array] = None,
    prefix_len: Optional[int] = None,
    window: Optional[int] = None,
    use_rope: bool = True,
) -> tuple[jax.Array, Optional[dict]]:
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = lshard(q, "batch", "seq", "heads", "head_dim")
    k = lshard(k, "batch", "seq", "kv_heads", "head_dim")
    v = lshard(v, "batch", "seq", "kv_heads", "head_dim")
    if use_rope:
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)

    if mode in ("train", "prefill"):
        out = chunked_attention(
            q, k, v,
            causal=True,
            window=window,
            prefix_len=prefix_len,  # python int or None (static for flash vjp)
            chunk=ATTN_CHUNK,
            logit_cap=cfg.logit_cap,
        )
        new_cache = None
        if mode == "prefill" and cache is not None:
            c_len = jax.tree.leaves(cache["k"])[0].shape[1]
            if c_len >= s:
                new_cache = {
                    "k": _write_full_cache(cache["k"], k, 0),
                    "v": _write_full_cache(cache["v"], v, 0),
                }
            else:  # sliding-window ring cache: keep the last c_len tokens
                idx = jnp.arange(s - c_len, s) % c_len
                new_cache = {
                    "k": _scatter_cache(cache["k"], k[:, s - c_len:], idx),
                    "v": _scatter_cache(cache["v"], v[:, s - c_len:], idx),
                }
    elif mode == "decode":
        assert cache is not None and step is not None
        c_len = jax.tree.leaves(cache["k"])[0].shape[1]
        ring = window is not None and c_len == window
        slot = jnp.mod(step, c_len) if ring else step
        kc = _write_full_cache(cache["k"], k, slot)
        vc = _write_full_cache(cache["v"], v, slot)
        kv_pos = ring_positions(step + 1, c_len) if ring else jnp.arange(c_len)
        out = chunked_attention(
            q, kc, vc,
            causal=True,
            window=window,
            prefix_len=prefix_len,  # python int or None (static for flash vjp)
            q_positions=jnp.reshape(step, (1,)),
            kv_positions=kv_pos,
            chunk=min(2048, c_len),
            logit_cap=cfg.logit_cap,
        )
        new_cache = {"k": kc, "v": vc}
    else:
        raise ValueError(mode)

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return lshard(y, "batch", "seq", "embed"), new_cache


# --------------------------------------------------------------------------
# decoder stack
# --------------------------------------------------------------------------
def _remat_policy(cfg: ArchConfig):
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    if cfg.remat == "none":
        return jax.checkpoint_policies.everything_saveable
    return None  # full recompute


def _layer_apply(p, cfg, x, cos, sin, *, is_moe, mode, cache, step, prefix_len):
    resid_scale = (
        1.0 if cfg.depth_scale is None else cfg.depth_scale / (cfg.n_layers ** 0.5)
    )
    h, new_cache = attn_apply(
        p["attn"], cfg, norm_apply(p["ln1"], cfg, x), cos, sin,
        mode=mode, cache=cache, step=step, prefix_len=prefix_len,
        window=cfg.attn_window,
    )
    x = x + h * resid_scale
    hn = norm_apply(p["ln2"], cfg, x)
    if is_moe:
        style = "sigmoid" if cfg.moe.top_k == 1 else "softmax"
        h2, aux = moe_lib.moe_ffn(hn, p["moe"], cfg.moe, router_style=style)
    else:
        h2 = mlp_apply(hn, p["mlp"], cfg.mlp_variant)
        aux = jnp.zeros((), jnp.float32)
    x = x + h2 * resid_scale
    return x, new_cache, aux


def decoder_hidden(
    params: dict,
    cfg: ArchConfig,
    embeds: jax.Array,              # (B, S, D)
    *,
    mode: str,
    cache: Optional[list] = None,   # per-slot {"k": (R, B, C, KH, hd), ...}
    step: Optional[jax.Array] = None,
    prefix_len: Optional[int] = None,
    positions: Optional[jax.Array] = None,
) -> tuple[jax.Array, Optional[list], jax.Array]:
    """Run the scanned decoder stack.  Returns (hidden, new_cache, aux_sum)."""
    pattern, repeats = _block_structure(cfg)
    if positions is None:
        if mode == "decode":
            positions = jnp.reshape(step, (1,))
        else:
            positions = jnp.arange(embeds.shape[1])
    cos, sin = rotary_cos_sin(positions, cfg.head_dim, cfg.rope_theta)

    x = embeds
    new_caches: list = []
    policy = _remat_policy(cfg)

    def block_step(xc, xs):
        xx, aux_sum = xc
        slot_params, slot_caches = xs
        new_slot_caches = []
        for si, is_moe in enumerate(pattern):
            xx, nc, aux = _layer_apply(
                slot_params[si], cfg, xx, cos, sin,
                is_moe=is_moe, mode=mode,
                cache=None if slot_caches is None else slot_caches[si],
                step=step, prefix_len=prefix_len,
            )
            new_slot_caches.append(nc)
        if any(c is not None for c in new_slot_caches):
            out_caches = new_slot_caches
        else:
            out_caches = None
        return (xx, aux_sum + aux), out_caches

    if cfg.remat != "none":
        block_step = jax.checkpoint(block_step, policy=policy)

    slot_caches = cache if cache is not None else None
    if slot_caches is None:
        (x, aux_sum), _ = jax.lax.scan(
            lambda c, ps: block_step(c, (ps, None)),
            (x, jnp.zeros((), jnp.float32)),
            params["blocks"],
        )
        new_cache = None
    else:
        (x, aux_sum), new_cache = jax.lax.scan(
            block_step, (x, jnp.zeros((), jnp.float32)),
            (params["blocks"], slot_caches),
        )
    x = norm_apply(params["final_norm"], cfg, x)
    return x, new_cache, aux_sum


# --------------------------------------------------------------------------
# embeddings / heads
# --------------------------------------------------------------------------
def embed_tokens(params: dict, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    emb = jnp.take(params["embed"], tokens, axis=0)
    emb = emb * jnp.asarray(cfg.emb_multiplier, emb.dtype)
    return lshard(emb, "batch", "seq", "embed")


def unembed_matrix(params: dict, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


# --------------------------------------------------------------------------
# task heads: loss / prefill / decode
# --------------------------------------------------------------------------
def _prep_embeds(params, cfg, batch) -> tuple[jax.Array, Optional[int], jax.Array]:
    """Token (+ optional multimodal prefix) embeddings.

    Returns (embeds, prefix_len, label_mask_extra) where labels at prefix
    positions are masked out of the loss.
    """
    tok_emb = embed_tokens(params, cfg, batch["tokens"])
    if cfg.num_prefix_tokens and "patches" in batch:
        prefix = batch["patches"].astype(tok_emb.dtype)
        prefix = lshard(prefix, "batch", "seq", "embed")
        embeds = jnp.concatenate([prefix, tok_emb], axis=1)
        return embeds, cfg.num_prefix_tokens, None
    return tok_emb, None, None


def lm_loss(params: dict, cfg: ArchConfig, batch: dict) -> tuple[jax.Array, dict]:
    embeds, prefix_len, _ = _prep_embeds(params, cfg, batch)
    hidden, _, aux = decoder_hidden(
        params, cfg, embeds, mode="train", prefix_len=prefix_len
    )
    if prefix_len:
        hidden = hidden[:, prefix_len:]
    loss_sum, count = sharded_xent_loss(
        hidden,
        unembed_matrix(params, cfg),
        batch["labels"],
        mask=batch.get("mask"),
        logit_divisor=cfg.logit_divisor,
    )
    loss = loss_sum / jnp.maximum(count, 1.0)
    if cfg.moe is not None:
        loss = loss + 0.01 * aux / cfg.n_layers
    return loss, {"xent": loss_sum / jnp.maximum(count, 1.0), "aux": aux}


def init_kv_cache(
    cfg: ArchConfig,
    batch_size: int,
    cache_len: int,
    *,
    quantized: bool = False,
    dtype=jnp.bfloat16,
) -> list:
    """Zero-initialized per-slot stacked KV cache for the scanned stack."""
    pattern, repeats = _block_structure(cfg)
    c_len = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
    kh, hd = cfg.n_kv_heads, cfg.head_dim

    def one():
        shape = (repeats, batch_size, c_len, kh, hd)
        if quantized:
            return QuantKV(
                q=jnp.zeros(shape, jnp.int8),
                scale=jnp.zeros(shape[:-1] + (1,), jnp.float32),
            )
        return jnp.zeros(shape, dtype)

    return [{"k": one(), "v": one()} for _ in pattern]


def lm_prefill(params: dict, cfg: ArchConfig, batch: dict, cache: list):
    """Prefill: returns (last-token logits, filled cache)."""
    embeds, prefix_len, _ = _prep_embeds(params, cfg, batch)
    hidden, new_cache, _ = decoder_hidden(
        params, cfg, embeds, mode="prefill", cache=cache, prefix_len=prefix_len
    )
    last = hidden[:, -1:]
    logits = jnp.einsum("bsd,dv->bsv", last.astype(jnp.bfloat16),
                        unembed_matrix(params, cfg).astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    logits = logits / cfg.logit_divisor
    return lshard(logits, "batch", None, "vocab"), new_cache


def lm_decode_step(params: dict, cfg: ArchConfig, cache: list, batch: dict,
                   step: jax.Array):
    """One decode step: batch["tokens"] is (B, 1).  Returns (logits, cache)."""
    embeds = embed_tokens(params, cfg, batch["tokens"])
    hidden, new_cache, _ = decoder_hidden(
        params, cfg, embeds, mode="decode", cache=cache, step=step,
        prefix_len=cfg.num_prefix_tokens or None,
    )
    logits = jnp.einsum("bsd,dv->bsv", hidden.astype(jnp.bfloat16),
                        unembed_matrix(params, cfg).astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    logits = logits / cfg.logit_divisor
    return lshard(logits, "batch", None, "vocab"), new_cache
