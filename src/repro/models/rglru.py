"""Griffin / RecurrentGemma hybrid (arXiv:2402.19427).

Temporal-mixing blocks follow the pattern (rec, rec, attn):

* recurrent block: GeLU(x W_gate) ⊙ RG-LRU(conv1d(x W_in)) -> W_out
  - RG-LRU: a_t = exp(-c*softplus(Λ)*r_t), r_t = σ(x W_a), i_t = σ(x W_x)
            h_t = a_t ⊙ h_{t-1} + sqrt(1-a_t²) ⊙ (i_t ⊙ x_t)
    computed with ``lax.associative_scan`` for train/prefill, single step
    for decode (state is O(1) -> long_500k lowers);
  - causal depthwise conv1d (width 4) with a 3-token cache for decode;
* local-attention block: sliding-window MQA (window 2048) with a ring
  cache -- decode memory bounded by the window, not the context;
* every temporal block is followed by a GeGLU MLP block.

38 layers = 12 x (rec, rec, attn) scanned superblocks + 2 rec tail layers.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.sharding import lshard
from repro.models.attention import QuantKV
from repro.models.layers import mlp_apply, rms_norm, rotary_cos_sin
from repro.models.params import Spec
from repro.models.transformer import (
    _attn_specs,
    _mlp_specs,
    attn_apply,
    stack_specs,
)
from repro.models.losses import sharded_xent_loss

__all__ = [
    "griffin_specs",
    "griffin_loss",
    "griffin_prefill",
    "griffin_decode_step",
    "init_griffin_state",
    "rglru_apply",
]

_C = 8.0  # Griffin's fixed recurrence sharpness


# --------------------------------------------------------------------------
# specs
# --------------------------------------------------------------------------
def _rec_block_specs(cfg: ArchConfig, dtype) -> dict:
    d, dr = cfg.d_model, cfg.recurrent.d_rnn
    w = cfg.recurrent.conv_width
    return {
        "w_gate": Spec((d, dr), ("p_fsdp", "p_mlp"), dtype=dtype, fan_in=d),
        "w_in": Spec((d, dr), ("p_fsdp", "p_mlp"), dtype=dtype, fan_in=d),
        "w_out": Spec((dr, d), ("p_mlp", "p_fsdp"), dtype=dtype, fan_in=dr),
        "conv_w": Spec((w, dr), (None, "p_mlp"), dtype=jnp.float32),
        "conv_b": Spec((dr,), ("p_mlp",), init="zeros", dtype=jnp.float32),
        "wa": Spec((dr, dr), ("p_mlp", None), dtype=dtype, fan_in=dr),
        "ba": Spec((dr,), (None,), init="zeros", dtype=jnp.float32),
        "wx": Spec((dr, dr), ("p_mlp", None), dtype=dtype, fan_in=dr),
        "bx": Spec((dr,), (None,), init="zeros", dtype=jnp.float32),
        "lam": Spec((dr,), (None,), init="ones", dtype=jnp.float32),
    }


def _norm(cfg) -> dict:
    return {"w": Spec((cfg.d_model,), (None,), init="zeros", dtype=jnp.float32)}


def _temporal_layer_specs(cfg: ArchConfig, kind: str, dtype) -> dict:
    body = (
        {"attn": _attn_specs(cfg, dtype)}
        if kind == "attn"
        else {"rec": _rec_block_specs(cfg, dtype)}
    )
    return {
        "ln1": _norm(cfg),
        **body,
        "ln2": _norm(cfg),
        "mlp": _mlp_specs(cfg, dtype),
    }


def _pattern_counts(cfg: ArchConfig) -> tuple[tuple[str, ...], int, tuple[str, ...]]:
    pat = cfg.recurrent.block_pattern
    repeats = cfg.n_layers // len(pat)
    tail = cfg.n_layers - repeats * len(pat)
    tail_kinds = pat[:tail]
    return pat, repeats, tail_kinds


def griffin_specs(cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    pat, repeats, tail = _pattern_counts(cfg)
    sp = {
        "embed": Spec((cfg.vocab_size, cfg.d_model), ("p_vocab", "p_fsdp"),
                      init="embed", dtype=dtype),
        "final_norm": _norm(cfg),
        "blocks": [
            stack_specs(_temporal_layer_specs(cfg, kind, dtype), repeats)
            for kind in pat
        ],
        "tail": [_temporal_layer_specs(cfg, kind, dtype) for kind in tail],
    }
    return sp


# --------------------------------------------------------------------------
# RG-LRU + conv
# --------------------------------------------------------------------------
def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 cache: Optional[jax.Array], mode: str):
    """Depthwise causal conv1d.  x: (B, T, C); w: (W, C); cache: (B, W-1, C)."""
    width = w.shape[0]
    xf = x.astype(jnp.float32)
    if mode == "decode":
        hist = jnp.concatenate([cache, xf], axis=1)      # (B, W, C)
        y = jnp.einsum("bwc,wc->bc", hist, w)[:, None] + b
        new_cache = hist[:, 1:]
        return y.astype(x.dtype), new_cache
    prev = jnp.pad(xf, ((0, 0), (width - 1, 0), (0, 0)))
    y = sum(
        prev[:, i : i + x.shape[1]] * w[i][None, None] for i in range(width)
    ) + b
    new_cache = prev[:, prev.shape[1] - (width - 1):] if cache is not None else None
    return y.astype(x.dtype), new_cache


def rglru_apply(p: dict, x: jax.Array, h0: Optional[jax.Array], mode: str):
    """RG-LRU over (B, T, C) with carry-in state h0 (B, C) f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(xf @ p["wx"].astype(jnp.float32) + p["bx"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r                   # (B, T, C) <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    if mode == "decode":
        h = a[:, 0] * h0 + gated[:, 0]
        return h[:, None].astype(x.dtype), h
    # associative scan over time: (a, b) ∘ (a', b') = (a'a, a'b + b')
    def combine(u, v):
        au, bu = u
        av, bv = v
        return au * av, bu * av + bv

    a_seq, b_seq = jax.lax.associative_scan(combine, (a, gated), axis=1)
    h_seq = b_seq if h0 is None else a_seq * h0[:, None] + b_seq
    return h_seq.astype(x.dtype), h_seq[:, -1]


def _rec_block(p, cfg, x, st, mode):
    gate = jax.nn.gelu(x @ p["w_gate"], approximate=True)
    gate = lshard(gate, "batch", "seq", "mlp")
    u = x @ p["w_in"]
    u = lshard(u, "batch", "seq", "mlp")
    u, conv_cache = _causal_conv(
        u, p["conv_w"], p["conv_b"],
        None if st is None else st["conv"], mode,
    )
    u, h_last = rglru_apply(p, u, None if st is None else st["h"], mode)
    out = (gate * u) @ p["w_out"]
    new_st = None
    if st is not None:
        new_st = {"conv": conv_cache, "h": h_last}
    return lshard(out, "batch", "seq", "embed"), new_st


# --------------------------------------------------------------------------
# full stack
# --------------------------------------------------------------------------
def _temporal_layer(p, cfg, kind, x, st, mode, cos, sin, step):
    xn = rms_norm(x, p["ln1"]["w"])
    if kind == "attn":
        h, new_kv = attn_apply(
            p["attn"], cfg, xn, cos, sin, mode=mode,
            cache=None if st is None else st, step=step,
            window=cfg.attn_window,
        )
        new_st = new_kv
    else:
        h, new_st = _rec_block(p["rec"], cfg, xn, st, mode)
    x = x + h
    x = x + mlp_apply(rms_norm(x, p["ln2"]["w"]), p["mlp"], cfg.mlp_variant)
    return x, new_st


def init_griffin_state(cfg: ArchConfig, batch: int, cache_len: int,
                       dtype=jnp.bfloat16) -> dict:
    pat, repeats, tail = _pattern_counts(cfg)
    w = cfg.attn_window or cache_len
    c_len = min(cache_len, w)
    dr, cw = cfg.recurrent.d_rnn, cfg.recurrent.conv_width
    kh, hd = cfg.n_kv_heads, cfg.head_dim

    def slot_state(kind, lead):
        if kind == "attn":
            return {
                "k": jnp.zeros(lead + (batch, c_len, kh, hd), dtype),
                "v": jnp.zeros(lead + (batch, c_len, kh, hd), dtype),
            }
        return {
            "conv": jnp.zeros(lead + (batch, cw - 1, dr), jnp.float32),
            "h": jnp.zeros(lead + (batch, dr), jnp.float32),
        }

    return {
        "blocks": [slot_state(kind, (repeats,)) for kind in pat],
        "tail": [slot_state(kind, ()) for kind in tail],
    }


def _stack(params, cfg, x, state, mode, step):
    pat, repeats, tail_kinds = _pattern_counts(cfg)
    if mode == "decode":
        positions = jnp.reshape(step, (1,))
    else:
        positions = jnp.arange(x.shape[1])
    cos, sin = rotary_cos_sin(positions, cfg.head_dim, cfg.rope_theta)

    def super_step(xc, xs):
        xx = xc
        slot_params, slot_states = xs
        new_states = []
        for si, kind in enumerate(pat):
            st = None if slot_states is None else slot_states[si]
            xx, ns = _temporal_layer(
                slot_params[si], cfg, kind, xx, st, mode, cos, sin, step
            )
            new_states.append(ns)
        if all(n is None for n in new_states):
            return xx, None
        return xx, new_states

    if cfg.remat != "none":
        super_step = jax.checkpoint(super_step)

    if state is None:
        x, _ = jax.lax.scan(
            lambda c, ps: super_step(c, (ps, None)), x, params["blocks"]
        )
        new_block_states = None
    else:
        x, new_block_states = jax.lax.scan(
            super_step, x, (params["blocks"], state["blocks"])
        )
    new_tail = []
    for ti, kind in enumerate(tail_kinds):
        st = None if state is None else state["tail"][ti]
        x, ns = _temporal_layer(params["tail"][ti], cfg, kind, x, st, mode, cos, sin, step)
        new_tail.append(ns)
    new_state = None
    if state is not None:
        new_state = {"blocks": new_block_states, "tail": new_tail}
    return x, new_state


def _embed(params, cfg, tokens):
    e = jnp.take(params["embed"], tokens, axis=0)
    e = e * jnp.asarray(cfg.emb_multiplier, e.dtype)
    return lshard(e, "batch", "seq", "embed")


def _logits(params, cfg, x):
    logits = jnp.einsum("btd,vd->btv", x.astype(jnp.bfloat16),
                        params["embed"].astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    return lshard(logits, "batch", None, "vocab")


def griffin_loss(params, cfg, batch):
    x = _embed(params, cfg, batch["tokens"])
    x, _ = _stack(params, cfg, x, None, "train", None)
    x = rms_norm(x, params["final_norm"]["w"])
    loss_sum, count = sharded_xent_loss(
        x, params["embed"].T, batch["labels"], mask=batch.get("mask")
    )
    loss = loss_sum / jnp.maximum(count, 1.0)
    return loss, {"xent": loss}


def griffin_prefill(params, cfg, batch, state):
    x = _embed(params, cfg, batch["tokens"])
    x, new_state = _stack(params, cfg, x, state, "prefill", None)
    x = rms_norm(x[:, -1:], params["final_norm"]["w"])
    return _logits(params, cfg, x), new_state


def griffin_decode_step(params, cfg, state, batch, step):
    x = _embed(params, cfg, batch["tokens"])
    x, new_state = _stack(params, cfg, x, state, "decode", step)
    x = rms_norm(x, params["final_norm"]["w"])
    return _logits(params, cfg, x), new_state
