"""Model zoo: all assigned architecture families, pure-JAX, scan-based."""

from repro.models.model_factory import BuiltModel, build_model

__all__ = ["BuiltModel", "build_model"]
