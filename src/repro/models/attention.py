"""Chunked (flash-style) attention in pure JAX.

One implementation serves every attention variant in the zoo:

* running-softmax accumulation over KV chunks (``lax.scan``) -- O(Sq*chunk)
  score memory instead of O(Sq*Skv), which is what lets 32k-prefill cells
  compile within per-device HBM and keeps the HLO small for 512-way GSPMD;
* **flash backward** (``custom_vjp``): the train/prefill path recomputes
  scores per chunk in the backward pass instead of letting autodiff save
  every chunk's probability matrix.  Without it, each layer's backward
  stashes O(Sq*Skv) f32 through HBM -- on llama4-400b train_4k that was
  ~5.4 GB/layer of per-chunk residuals; with it, only q/k/v/out/lse
  survive the forward.  This is the TPU-idiomatic equivalent of the flash
  attention kernel, expressed at the XLA level so GSPMD still shards it;
* GQA/MQA via query-group reshape (no KV repetition in memory);
* causal / bidirectional / prefix-LM / sliding-window masks from position
  vectors, so ring-buffer caches (positions out of slot order) just work;
* int8-quantized KV chunks dequantized on the fly inside the scan
  (per-token, per-head scales) -- the cache never materializes in bf16.

Layouts: q (B, Sq, H, D); k, v (B, Skv, KH, D); output (B, Sq, H, D).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.distributed.sharding import lshard
from repro.models.layers import softcap

__all__ = ["QuantKV", "quantize_kv", "dequantize_kv", "chunked_attention", "ring_positions"]

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


@dataclasses.dataclass
class QuantKV:
    """Int8 tensor + per-(token, head) scale.  Registered as a pytree."""

    q: jax.Array       # int8, (..., D)
    scale: jax.Array   # f32,  (..., 1)


jax.tree_util.register_dataclass(QuantKV, data_fields=["q", "scale"], meta_fields=[])


def quantize_kv(x: jax.Array) -> QuantKV:
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return QuantKV(q=q, scale=scale)


def dequantize_kv(x: Union[jax.Array, QuantKV], dtype=jnp.bfloat16) -> jax.Array:
    if isinstance(x, QuantKV):
        return (x.q.astype(jnp.float32) * x.scale).astype(dtype)
    return x


def ring_positions(step: jax.Array, window: int) -> jax.Array:
    """Absolute positions held by each ring-buffer slot after ``step`` writes.

    Slot ``i`` holds position ``p = step-1 - ((step-1-i) mod W)``; negative
    values mean the slot has not been written yet (masked out).
    """
    i = jnp.arange(window)
    last = step - 1
    p = last - jnp.mod(last - i, window)
    return jnp.where(p >= 0, p, -1)


def _split_chunks(x, n_chunks: int, chunk: int):
    """(B, S, ...) -> (n_chunks, B, chunk, ...) for lax.scan."""

    def go(leaf):
        b, s = leaf.shape[:2]
        leaf = leaf.reshape((b, n_chunks, chunk) + leaf.shape[2:])
        return jnp.moveaxis(leaf, 1, 0)

    return jax.tree.map(go, x)


def _chunk_mask(qpos, pc, causal, window, prefix_len):
    """(Sq, C) allowed mask from query/chunk position vectors."""
    allowed = pc[None, :] >= 0
    if causal:
        allowed = allowed & (pc[None, :] <= qpos[:, None])
    if window is not None:
        allowed = allowed & (pc[None, :] > qpos[:, None] - window)
    if prefix_len is not None:
        allowed = allowed | ((pc[None, :] < prefix_len) & (pc[None, :] >= 0))
    return allowed


# --------------------------------------------------------------------------
# flash train/prefill path: custom_vjp with per-chunk recompute in backward
# --------------------------------------------------------------------------
import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(cfg: tuple, q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    out, _ = _flash_fwd(cfg, q, k, v)
    return out


def _flash_plan(cfg, q, k):
    causal, window, prefix_len, chunk, logit_cap, scale = cfg
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    g = h // kh
    chunk = min(chunk, skv)
    pad = (chunk - skv % chunk) % chunk
    n_chunks = (skv + pad) // chunk
    qpos = jnp.arange(sq)
    kvpos = jnp.pad(jnp.arange(skv), (0, pad), constant_values=-1)
    return b, sq, h, d, skv, kh, g, chunk, pad, n_chunks, qpos, kvpos


def _flash_fwd(cfg, q, k, v):
    causal, window, prefix_len, chunk, logit_cap, scale = cfg
    b, sq, h, d, skv, kh, g, chunk, pad, n_chunks, qpos, kvpos = _flash_plan(cfg, q, k)

    qf = jnp.transpose(q.reshape(b, sq, kh, g, d), (0, 2, 3, 1, 4)).astype(jnp.float32)
    kp = jnp.pad(k, [(0, 0), (0, pad), (0, 0), (0, 0)]) if pad else k
    vp = jnp.pad(v, [(0, 0), (0, pad), (0, 0), (0, 0)]) if pad else v
    ks = _split_chunks(kp, n_chunks, chunk)
    vs = _split_chunks(vp, n_chunks, chunk)
    pcs = kvpos.reshape(n_chunks, chunk)

    def step(carry, xs):
        acc, m_run, l_run = carry
        kc, vc, pc = xs
        kc = jnp.transpose(kc.astype(jnp.float32), (0, 2, 1, 3))
        vc = jnp.transpose(vc.astype(jnp.float32), (0, 2, 1, 3))
        scores = jnp.einsum("bhgsd,bhcd->bhgsc", qf * scale, kc)
        scores = softcap(scores, logit_cap)
        allowed = _chunk_mask(qpos, pc, causal, window, prefix_len)
        scores = jnp.where(allowed[None, None, None], scores, _NEG_INF)
        m_new = jnp.maximum(m_run, scores.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(scores - m_new[..., None]) * allowed[None, None, None]
        l_new = l_run * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhgsc,bhcd->bhgsd", p, vc)
        return (acc_new, m_new, l_new), None

    init = (
        jnp.zeros((b, kh, g, sq, d), jnp.float32),
        jnp.full((b, kh, g, sq), _NEG_INF, jnp.float32),
        jnp.zeros((b, kh, g, sq), jnp.float32),
    )
    (acc, m_run, l_run), _ = jax.lax.scan(step, init, (ks, vs, pcs))
    l_safe = jnp.maximum(l_run, 1e-20)
    out5 = acc / l_safe[..., None]
    lse = m_run + jnp.log(l_safe)
    out = jnp.transpose(out5, (0, 3, 1, 2, 4)).reshape(b, sq, h, d).astype(q.dtype)
    return out, (q, k, v, out5, lse)


def _flash_bwd(cfg, res, dout):
    causal, window, prefix_len, chunk, logit_cap, scale = cfg
    q, k, v, out5, lse = res
    b, sq, h, d, skv, kh, g, chunk, pad, n_chunks, qpos, kvpos = _flash_plan(cfg, q, k)

    qf = jnp.transpose(q.reshape(b, sq, kh, g, d), (0, 2, 3, 1, 4)).astype(jnp.float32)
    do5 = jnp.transpose(dout.reshape(b, sq, kh, g, d), (0, 2, 3, 1, 4)).astype(jnp.float32)
    delta = jnp.sum(do5 * out5, axis=-1)                      # (B,KH,G,Sq)

    kp = jnp.pad(k, [(0, 0), (0, pad), (0, 0), (0, 0)]) if pad else k
    vp = jnp.pad(v, [(0, 0), (0, pad), (0, 0), (0, 0)]) if pad else v
    ks = _split_chunks(kp, n_chunks, chunk)
    vs = _split_chunks(vp, n_chunks, chunk)
    pcs = kvpos.reshape(n_chunks, chunk)

    def step(dq_acc, xs):
        kc0, vc0, pc = xs
        kc = jnp.transpose(kc0.astype(jnp.float32), (0, 2, 1, 3))  # (B,KH,C,D)
        vc = jnp.transpose(vc0.astype(jnp.float32), (0, 2, 1, 3))
        raw = jnp.einsum("bhgsd,bhcd->bhgsc", qf * scale, kc)
        sc = softcap(raw, logit_cap)          # unmasked (finite) capped scores
        allowed = _chunk_mask(qpos, pc, causal, window, prefix_len)
        s = jnp.where(allowed[None, None, None], sc, _NEG_INF)
        p = jnp.exp(s - lse[..., None]) * allowed[None, None, None]
        dv_c = jnp.einsum("bhgsc,bhgsd->bhcd", p, do5)
        dp = jnp.einsum("bhgsd,bhcd->bhgsc", do5, vc)
        ds = p * (dp - delta[..., None])
        if logit_cap is not None:
            # d softcap(x)/dx = 1 - tanh^2 = 1 - (capped/cap)^2, from the
            # UNMASKED scores (masked entries already have ds == 0 via p)
            ds = ds * (1.0 - jnp.square(sc / logit_cap))
        dq_acc = dq_acc + jnp.einsum("bhgsc,bhcd->bhgsd", ds, kc) * scale
        dk_c = jnp.einsum("bhgsc,bhgsd->bhcd", ds, qf) * scale
        return dq_acc, (dk_c, dv_c)

    dq0 = jnp.zeros((b, kh, g, sq, d), jnp.float32)
    dq5, (dks, dvs) = jax.lax.scan(step, dq0, (ks, vs, pcs))

    dq = jnp.transpose(dq5, (0, 3, 1, 2, 4)).reshape(b, sq, h, d).astype(q.dtype)

    def unsplit(ch):  # (n, B, KH, C, D) -> (B, S, KH, D)
        ch = jnp.moveaxis(ch, 0, 1)                # (B, n, KH, C, D)
        ch = jnp.moveaxis(ch, 2, 3)                # (B, n, C, KH, D)
        full = ch.reshape(b, n_chunks * chunk, kh, d)
        return full[:, :skv]

    dk = unsplit(dks).astype(k.dtype)
    dv = unsplit(dvs).astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def chunked_attention(
    q: jax.Array,
    k: Union[jax.Array, QuantKV],
    v: Union[jax.Array, QuantKV],
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: Optional[jax.Array] = None,
    q_positions: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    chunk: int = 1024,
    logit_cap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Flash-style attention over KV chunks.  See module docstring."""
    b, sq, h, d = q.shape
    kv_leaves = jax.tree.leaves(k)
    skv, kh = kv_leaves[0].shape[1], kv_leaves[0].shape[2]
    assert h % kh == 0, (h, kh)
    g = h // kh
    if scale is None:
        scale = d ** -0.5

    # flash custom_vjp path: differentiable train/prefill attention with
    # natural positions and unquantized KV (decode/ring paths keep the
    # plain scan -- they are never differentiated)
    if (q_positions is None and kv_positions is None
            and not isinstance(k, QuantKV) and not isinstance(v, QuantKV)
            and isinstance(prefix_len, (int, type(None)))):
        cfg = (causal, window, prefix_len, chunk, logit_cap, float(scale))
        out = _flash(cfg, q, k, v)
        return lshard(out, "batch", "seq", "heads", "head_dim")

    if q_positions is None:
        q_positions = jnp.arange(sq)
    if kv_positions is None:
        kv_positions = jnp.arange(skv)

    chunk = min(chunk, skv)
    if skv % chunk != 0:  # pad KV (padded slots masked via position = -1)
        pad = chunk - skv % chunk
        k = jax.tree.map(lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)), k)
        v = jax.tree.map(lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)), v)
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-1)
        skv += pad
    n_chunks = skv // chunk

    qg = q.reshape(b, sq, kh, g, d)
    qg = jnp.transpose(qg, (0, 2, 3, 1, 4))  # (B, KH, G, Sq, D)
    qf = qg.astype(jnp.float32) * scale

    ks = _split_chunks(k, n_chunks, chunk)
    vs = _split_chunks(v, n_chunks, chunk)
    pos_chunks = kv_positions.reshape(n_chunks, chunk)

    qpos = q_positions.astype(jnp.int32)

    def step(carry, xs):
        acc, m_run, l_run = carry
        kc, vc, pc = xs
        kc = dequantize_kv(kc).astype(jnp.float32)  # (B, chunk, KH, D)
        vc = dequantize_kv(vc).astype(jnp.float32)
        kc = jnp.transpose(kc, (0, 2, 1, 3))  # (B, KH, C, D)
        vc = jnp.transpose(vc, (0, 2, 1, 3))
        scores = jnp.einsum("bhgsd,bhcd->bhgsc", qf, kc)
        scores = softcap(scores, logit_cap)
        allowed = pc[None, :] >= 0  # (1, C) valid slots
        if causal:
            allowed = allowed & (pc[None, :] <= qpos[:, None])
        if window is not None:
            allowed = allowed & (pc[None, :] > qpos[:, None] - window)
        if prefix_len is not None:
            allowed = allowed | ((pc[None, :] < prefix_len) & (pc[None, :] >= 0))
        scores = jnp.where(allowed[None, None, None], scores, _NEG_INF)
        m_new = jnp.maximum(m_run, scores.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        # explicit zeroing keeps fully-masked rows at p == 0 (not uniform)
        p = jnp.exp(scores - m_new[..., None]) * allowed[None, None, None]
        l_new = l_run * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhgsc,bhcd->bhgsd", p, vc)
        return (acc_new, m_new, l_new), None

    init = (
        jnp.zeros((b, kh, g, sq, d), jnp.float32),
        jnp.full((b, kh, g, sq), _NEG_INF, jnp.float32),
        jnp.zeros((b, kh, g, sq), jnp.float32),
    )
    (acc, _, l_run), _ = jax.lax.scan(step, init, (ks, vs, pos_chunks))
    out = acc / jnp.maximum(l_run[..., None], 1e-20)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, sq, h, d)
    out = out.astype(q.dtype)
    return lshard(out, "batch", "seq", "heads", "head_dim")
