"""Shared neural-net building blocks (pure functions, explicit params)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import lshard

__all__ = [
    "rms_norm",
    "layer_norm",
    "mlp_apply",
    "rotary_cos_sin",
    "apply_rotary",
    "sinusoidal_positions",
    "softcap",
]


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6,
             zero_centered: bool = True) -> jax.Array:
    """RMSNorm in f32 with bf16 in/out.  ``zero_centered`` follows the
    Gemma/Griffin convention of storing ``weight - 1``."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if zero_centered:
        w = w + 1.0
    return (xf * w).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def mlp_apply(x: jax.Array, p: dict, variant: str) -> jax.Array:
    """Gated / plain MLP.  ``p``: {wi, wg?, wo, bi?, bo?}.

    variant: swiglu | geglu | gelu (plain 2-layer).
    Activations annotated with the 'mlp' logical axis for TP.
    """
    if variant in ("swiglu", "geglu"):
        h = x @ p["wi"]
        g = x @ p["wg"]
        h = lshard(h, "batch", "seq", "mlp")
        g = lshard(g, "batch", "seq", "mlp")
        act = "silu" if variant == "swiglu" else "gelu"
        h = _act(g, act) * h
    elif variant == "gelu":
        h = x @ p["wi"]
        if "bi" in p:
            h = h + p["bi"]
        h = lshard(h, "batch", "seq", "mlp")
        h = _act(h, "gelu")
    else:
        raise ValueError(variant)
    out = h @ p["wo"]
    if "bo" in p:
        out = out + p["bo"]
    return lshard(out, "batch", "seq", "embed")


def rotary_cos_sin(positions: jax.Array, head_dim: int,
                   theta: float = 10000.0) -> tuple[jax.Array, jax.Array]:
    """RoPE tables for integer ``positions`` (any shape) -> (..., head_dim/2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Apply RoPE. ``x``: (..., positions..., n_heads, head_dim); cos/sin
    broadcast over the head axis: (positions..., head_dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1
    ).astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings (length, dim), f32."""
    half = dim // 2
    scale = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * math.log(10000.0) / (half - 1))
    ang = jnp.arange(length, dtype=jnp.float32)[:, None] * scale[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap
