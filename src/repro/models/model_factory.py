"""Uniform model API over all architecture families.

``build_model(cfg)`` returns a ``BuiltModel`` exposing:

* ``specs``                 parameter descriptor tree (shape+init+sharding)
* ``init(key)``             materialized params
* ``loss / prefill / decode_step``  pure functions
* ``init_cache(batch, cache_len)``  decode state (KV / recurrent / ring)
* ``input_specs(shape)``    ShapeDtypeStruct stand-ins for the dry-run
* ``n_params / n_active_params``    for 6·N·D roofline bookkeeping
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, ShapeConfig
from repro.models import encdec, rglru, rwkv6, transformer
from repro.models.params import Spec, count_params, init_params

__all__ = ["BuiltModel", "build_model"]


@dataclasses.dataclass
class BuiltModel:
    cfg: ArchConfig
    specs: Any
    loss: Callable                       # (params, batch) -> (loss, metrics)
    prefill: Callable                    # (params, batch, cache) -> (logits, cache)
    decode_step: Callable                # (params, cache, batch, step) -> (logits, cache)
    init_cache: Callable                 # (batch, cache_len, quantized) -> cache
    n_params: int
    n_active_params: int

    def init(self, key: jax.Array):
        return init_params(self.specs, key)

    # ---------------- input specs for lowering ------------------------------
    def input_specs(self, shape: ShapeConfig) -> dict:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)
        emb = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.bfloat16)
        if shape.kind == "train":
            if cfg.family == "encdec":
                return {
                    "frames": emb(b, s, cfg.d_model),
                    "tokens": tok(b, s),
                    "labels": tok(b, s),
                }
            if cfg.family == "vlm":
                text = s - cfg.num_prefix_tokens
                return {
                    "patches": emb(b, cfg.num_prefix_tokens, cfg.d_model),
                    "tokens": tok(b, text),
                    "labels": tok(b, text),
                }
            return {"tokens": tok(b, s), "labels": tok(b, s)}
        if shape.kind == "prefill":
            if cfg.family == "encdec":
                return {"frames": emb(b, s, cfg.d_model), "tokens": tok(b, s)}
            if cfg.family == "vlm":
                return {
                    "patches": emb(b, cfg.num_prefix_tokens, cfg.d_model),
                    "tokens": tok(b, s - cfg.num_prefix_tokens),
                }
            return {"tokens": tok(b, s)}
        # decode: one new token against a cache of length s
        return {"tokens": tok(b, 1)}


def _count_active(cfg: ArchConfig, specs) -> int:
    total = count_params(specs)
    if cfg.moe is None:
        return total
    moe = cfg.moe
    expert_params_per_layer = 3 * cfg.d_model * moe.d_ff_expert  # wi, wg, wo
    n_moe_layers = sum(cfg.moe_layer_flags)
    inactive = n_moe_layers * (moe.num_experts - moe.top_k) * expert_params_per_layer
    return total - inactive


def build_model(cfg: ArchConfig, dtype=jnp.bfloat16) -> BuiltModel:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        specs = transformer.transformer_specs(cfg, dtype)
        loss = lambda p, b: transformer.lm_loss(p, cfg, b)
        prefill = lambda p, b, c: transformer.lm_prefill(p, cfg, b, c)
        decode = lambda p, c, b, s: transformer.lm_decode_step(p, cfg, c, b, s)
        init_cache = lambda batch, cache_len, quantized=False: transformer.init_kv_cache(
            cfg, batch, cache_len, quantized=quantized, dtype=dtype
        )
    elif fam == "ssm":
        specs = rwkv6.rwkv_specs(cfg, dtype)
        loss = lambda p, b: rwkv6.rwkv_loss(p, cfg, b)
        prefill = lambda p, b, c: rwkv6.rwkv_prefill(p, cfg, b, c)
        decode = lambda p, c, b, s: rwkv6.rwkv_decode_step(p, cfg, c, b, s)
        init_cache = lambda batch, cache_len, quantized=False: rwkv6.init_rwkv_state(
            cfg, batch
        )
    elif fam == "hybrid":
        specs = rglru.griffin_specs(cfg, dtype)
        loss = lambda p, b: rglru.griffin_loss(p, cfg, b)
        prefill = lambda p, b, c: rglru.griffin_prefill(p, cfg, b, c)
        decode = lambda p, c, b, s: rglru.griffin_decode_step(p, cfg, c, b, s)
        init_cache = lambda batch, cache_len, quantized=False: rglru.init_griffin_state(
            cfg, batch, cache_len, dtype=dtype
        )
    elif fam == "encdec":
        specs = encdec.encdec_specs(cfg, dtype)
        loss = lambda p, b: encdec.encdec_loss(p, cfg, b)
        prefill = lambda p, b, c: encdec.encdec_prefill(p, cfg, b, c)
        decode = lambda p, c, b, s: encdec.encdec_decode_step(p, cfg, c, b, s)
        init_cache = lambda batch, cache_len, quantized=False: encdec.init_encdec_cache(
            cfg, batch, cache_len, dtype=dtype
        )
    else:
        raise ValueError(f"unknown family {fam}")

    return BuiltModel(
        cfg=cfg,
        specs=specs,
        loss=loss,
        prefill=prefill,
        decode_step=decode,
        init_cache=init_cache,
        n_params=count_params(specs),
        n_active_params=_count_active(cfg, specs),
    )
