"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free token mixing with
data-dependent decay.

Recurrence per head (state S in R^{K x V}, head size 64):

    o_t = r_t · (diag(u)·k_t v_t^T + S_{t-1})
    S_t = diag(w_t)·S_{t-1} + k_t v_t^T

with w_t = exp(-exp(decay_base + lora(x_t)))  (data-dependent decay) and
DDLerp token-shift mixing for the r/k/v/w/g projections.

Training/prefill use a *chunked* parallel form: within a chunk all decay
exponents are differences of a running log-decay cumsum and hence <= 0
(numerically safe); inter-chunk state propagation is a pair of einsums (MXU
work).  Decode is the exact single-step recurrence; both paths are tested
against each other and against a naive per-token scan.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.sharding import lshard
from repro.models.layers import layer_norm
from repro.models.params import Spec

__all__ = [
    "rwkv_specs",
    "rwkv_loss",
    "rwkv_prefill",
    "rwkv_decode_step",
    "init_rwkv_state",
    "wkv_chunked",
    "wkv_scan_reference",
]

_CHUNK = 16


# --------------------------------------------------------------------------
# specs
# --------------------------------------------------------------------------
def _layer(cfg: ArchConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    h, hs = cfg.n_heads, cfg.rwkv.head_size
    lw, lg, lm = cfg.rwkv.decay_lora, cfg.rwkv.gate_lora, cfg.rwkv.mix_lora
    tm = {
        # DDLerp token-shift: base mixes + data-dependent delta LoRA
        "maa_base": Spec((5, d), (None, None), init="zeros", dtype=jnp.float32),
        "maa_x": Spec((d,), (None,), init="zeros", dtype=jnp.float32),
        "maa_w1": Spec((d, 5 * lm), ("p_fsdp", None), dtype=dtype),
        "maa_w2": Spec((5, lm, d), (None, None, "p_fsdp"), dtype=dtype),
        # projections (head-parallel over 'model')
        "wr": Spec((d, h, hs), ("p_fsdp", "p_heads", None), dtype=dtype, fan_in=d),
        "wk": Spec((d, h, hs), ("p_fsdp", "p_heads", None), dtype=dtype, fan_in=d),
        "wv": Spec((d, h, hs), ("p_fsdp", "p_heads", None), dtype=dtype, fan_in=d),
        "wg": Spec((d, h, hs), ("p_fsdp", "p_heads", None), dtype=dtype, fan_in=d),
        "wo": Spec((h, hs, d), ("p_heads", None, "p_fsdp"), dtype=dtype, fan_in=d),
        # data-dependent decay
        "decay_base": Spec((h, hs), ("p_heads", None), init="zeros", dtype=jnp.float32),
        "decay_w1": Spec((d, lw), ("p_fsdp", None), dtype=dtype),
        "decay_w2": Spec((lw, h, hs), (None, "p_heads", None), dtype=dtype),
        # bonus
        "u": Spec((h, hs), ("p_heads", None), init="zeros", dtype=jnp.float32),
        # per-head group norm
        "gn_w": Spec((d,), (None,), init="ones", dtype=jnp.float32),
        "gn_b": Spec((d,), (None,), init="zeros", dtype=jnp.float32),
    }
    cm = {
        "mix_k": Spec((d,), (None,), init="zeros", dtype=jnp.float32),
        "mix_r": Spec((d,), (None,), init="zeros", dtype=jnp.float32),
        "wk": Spec((d, f), ("p_fsdp", "p_mlp"), dtype=dtype, fan_in=d),
        "wv": Spec((f, d), ("p_mlp", "p_fsdp"), dtype=dtype, fan_in=f),
        "wr": Spec((d, d), ("p_fsdp", None), dtype=dtype, fan_in=d),
    }
    return {
        "ln1": {"w": Spec((d,), (None,), init="ones", dtype=jnp.float32),
                "b": Spec((d,), (None,), init="zeros", dtype=jnp.float32)},
        "ln2": {"w": Spec((d,), (None,), init="ones", dtype=jnp.float32),
                "b": Spec((d,), (None,), init="zeros", dtype=jnp.float32)},
        "time_mix": tm,
        "channel_mix": cm,
    }


def rwkv_specs(cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    from repro.models.transformer import stack_specs

    d = cfg.d_model
    return {
        "embed": Spec((cfg.vocab_size, d), ("p_vocab", "p_fsdp"), init="embed", dtype=dtype),
        "unembed": Spec((d, cfg.vocab_size), ("p_fsdp", "p_vocab"), dtype=dtype, fan_in=d),
        "ln_in": {"w": Spec((d,), (None,), init="ones", dtype=jnp.float32),
                  "b": Spec((d,), (None,), init="zeros", dtype=jnp.float32)},
        "final_norm": {"w": Spec((d,), (None,), init="ones", dtype=jnp.float32),
                       "b": Spec((d,), (None,), init="zeros", dtype=jnp.float32)},
        "layers": stack_specs(_layer(cfg, dtype), cfg.n_layers),
    }


# --------------------------------------------------------------------------
# WKV core
# --------------------------------------------------------------------------
def wkv_scan_reference(r, k, v, logw, u, state):
    """Exact per-token recurrence (oracle for tests).

    r/k/v/logw: (B, T, H, K) f32 (logw = log decay, <= 0); u: (H, K);
    state: (B, H, K, V=K).
    """

    def step(s, xs):
        rt, kt, vt, lwt = xs  # (B, H, K)
        bonus = jnp.einsum("bhk,bhv->bhkv", kt * u[None], vt)
        o = jnp.einsum("bhk,bhkv->bhv", rt, s + bonus)
        s = s * jnp.exp(lwt)[..., None] + jnp.einsum("bhk,bhv->bhkv", kt, vt)
        return s, o

    xs = jax.tree.map(lambda x: jnp.moveaxis(x, 1, 0), (r, k, v, logw))
    state, o = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(o, 0, 1), state


def wkv_chunked(r, k, v, logw, u, state, chunk: int = _CHUNK,
                stream_dtype=jnp.bfloat16):
    """Chunked parallel form; exact (up to fp) match of the scan reference
    when ``stream_dtype`` is f32 (tests); bf16 streaming by default.

    Takes the decay in log space (``logw <= 0``) so strong decays never
    round-trip through an f32-underflowing ``exp``/``log`` pair (which is
    both a forward -inf and a backward 1/0 hazard).

    The intra-chunk decay weight factorizes EXACTLY:
        exp(pm1_t - p_s) = exp(pm1_t - c) * exp(c - p_s)
    for any per-(b,h,k) constant c, so the (B, Ct, Cs, H, K) pairwise decay
    tensor of the naive form never materializes -- that tensor made
    rwkv6-3b train_4k the worst memory-bound cell in the roofline table
    (2.0e15 bytes/chip; see EXPERIMENTS.md §Perf).  We center at the
    mid-chunk cumsum so each factor's exponent is bounded by
    (chunk/2)*|logw|_max; with the model-level decay clamp logw >= -8 and
    chunk=16 each factor stays <= e^64 (finite in f32).  Masked-out score
    entries may still overflow in the PRODUCT; the select-mask below
    discards them before they can poison anything (see inline comment).
    """
    b, t, h, kdim = r.shape
    pad = (-t) % chunk
    if pad:
        zero = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, logw = zero(r), zero(k), zero(v), zero(logw)
    tt = t + pad
    n = tt // chunk
    # Stream chunks with dynamic_slice instead of pre-stacking (n, B, C, H,
    # K) scan inputs: the moveaxis copies (plus their backward scatter
    # twins) dominated this cell's HBM bytes (2.3e14 of 5.6e14 per chip --
    # §Perf iteration 3).  r/k/v additionally stream in the model dtype
    # (``stream_dtype``) and are promoted per chunk; decay stays f32 for
    # the cumsum.
    rs = r.astype(stream_dtype)
    ks = k.astype(stream_dtype)
    vs = v.astype(stream_dtype)
    mask = jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :]  # bool

    f32 = jnp.float32
    # dots run in stream_dtype (CPU's DotThunk rejects bf16->f32 preferred
    # accumulation; on TPU the bf16 dot hits the MXU either way); the state
    # carry accumulates in f32 explicitly.
    acc = {}

    def chunk_step(s, i):
        sl = lambda x: jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
        rc = sl(rs)                              # (B, C, H, K), stream_dtype
        kc = sl(ks)
        vc = sl(vs)
        lwc = sl(logw)                           # f32: cumsum precision
        p = jnp.cumsum(lwc, axis=1)              # (B, C, H, K), decreasing
        pm1 = jnp.concatenate([jnp.zeros_like(p[:, :1]), p[:, :-1]], axis=1)
        # inter-chunk: r_t decayed to chunk start, against carried state
        r0 = (rc.astype(f32) * jnp.exp(pm1)).astype(stream_dtype)
        o_inter = jnp.einsum("bthk,bhkv->bthv", r0, s.astype(stream_dtype), **acc)
        # intra-chunk, factorized: exp(pm1_t - p_s) = exp(pm1_t-c) exp(c-p_s)
        c = p[:, chunk // 2][:, None]            # (B, 1, H, K) re-centering
        r_dec = (rc.astype(f32) * jnp.exp(pm1 - c)).astype(stream_dtype)
        k_grow = (kc.astype(f32) * jnp.exp(c - p)).astype(stream_dtype)
        scores = jnp.einsum("bthk,bshk->bhts", r_dec, k_grow, **acc)
        # SELECT mask, not multiply: each factor is finite (exponent <=
        # (chunk/2)*8 = 64), but masked-pair PRODUCTS can overflow to
        # inf/NaN inside the dot -- select discards those entries, and the
        # backward stays finite because the cotangent is exactly zero where
        # the factors are extreme (hypothesis-found at chunk=16 with
        # multiply-masking; chunk=8 halved the hazard but doubled the
        # scan's saved state stack, +2.4x memory term -- see §Perf R5/R6).
        scores = jnp.where(mask[None, None], scores, 0).astype(stream_dtype)
        o_intra = jnp.einsum("bhts,bshv->bthv", scores, vc, **acc)
        # diagonal bonus term
        coef = jnp.einsum("bthk,bthk,hk->bth", rc.astype(f32), kc.astype(f32), u)
        o_diag = coef[..., None] * vc.astype(f32)
        # state to chunk end
        pe = p[:, -1]                                           # (B, H, K)
        kdec = (kc.astype(f32) * jnp.exp(pe[:, None] - p)).astype(stream_dtype)
        s_new = s * jnp.exp(pe)[..., None] + jnp.einsum(
            "bshk,bshv->bhkv", kdec, vc, **acc).astype(f32)
        o_chunk = (o_inter.astype(f32) + o_intra.astype(f32) + o_diag
                   ).astype(stream_dtype)
        return s_new, o_chunk

    state, o = jax.lax.scan(chunk_step, state, jnp.arange(n))
    o = jnp.moveaxis(o, 0, 1).reshape(b, tt, h, kdim).astype(jnp.float32)
    return o[:, :t], state


# --------------------------------------------------------------------------
# layer application
# --------------------------------------------------------------------------
def _token_shift(x, last):
    """x_{t-1} with ``last`` filling position 0.  x: (B, T, D); last: (B, D)."""
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _time_mix(p, cfg, x, last_x, state, mode):
    b, t, d = x.shape
    h, hs = cfg.n_heads, cfg.rwkv.head_size
    # Mixing/DDLerp chain stays in the model dtype (bf16): these tensors are
    # (B, T, 5, D)-sized selection coefficients feeding bf16 einsums, and
    # keeping them f32 doubled the HBM traffic of the whole layer (roofline
    # §Perf iteration 2).  Only the wkv recurrence inputs and the decay are
    # promoted to f32 (state dynamics need the precision).
    xf = x.astype(jnp.float32)
    xb = x
    prev = _token_shift(xb, last_x.astype(x.dtype))
    xx = prev - xb
    # DDLerp
    xxx = xb + xx * p["maa_x"].astype(x.dtype)
    lora = jnp.einsum("btd,dm->btm", xxx, p["maa_w1"])
    lora = jnp.tanh(lora.reshape(b, t, 5, -1).astype(jnp.float32)).astype(x.dtype)
    delta = jnp.einsum("btfm,fmd->btfd", lora, p["maa_w2"])
    mixes = p["maa_base"][None, None].astype(x.dtype) + delta     # (B, T, 5, D)
    xw, xk, xv, xr, xg = [xb + xx * mixes[:, :, i] for i in range(5)]

    r = jnp.einsum("btd,dhk->bthk", xr, p["wr"]).astype(jnp.float32)
    k = jnp.einsum("btd,dhk->bthk", xk, p["wk"]).astype(jnp.float32)
    v = jnp.einsum("btd,dhk->bthk", xv, p["wv"]).astype(jnp.float32)
    g = jnp.einsum("btd,dhk->bthk", xg, p["wg"])
    r = lshard(r, "batch", "seq", "heads", "head_dim")
    k = lshard(k, "batch", "seq", "heads", "head_dim")
    v = lshard(v, "batch", "seq", "heads", "head_dim")

    dlora = jnp.tanh(jnp.einsum("btd,dl->btl", xw.astype(x.dtype), p["decay_w1"]))
    dd = jnp.einsum("btl,lhk->bthk", dlora, p["decay_w2"]).astype(jnp.float32)
    # log-decay clamped to [-8, ~0): e^-8/token zeroes the state within a
    # couple of tokens (semantically "forget now"), while keeping the
    # factorized chunked kernel's exponents inside f32 range (see
    # wkv_chunked) and grads finite.  Applied identically in train/prefill
    # (wkv_chunked) and decode (direct recurrence) so the paths agree.
    logw = -jnp.exp(jnp.clip(p["decay_base"][None, None] + dd, -10.0, 4.0))
    logw = jnp.maximum(logw, -8.0)

    u = p["u"]
    if mode == "decode":
        rt, kt, vt, lwt = r[:, 0], k[:, 0], v[:, 0], logw[:, 0]
        bonus = jnp.einsum("bhk,bhv->bhkv", kt * u[None], vt)
        o = jnp.einsum("bhk,bhkv->bhv", rt, state + bonus)[:, None]
        new_state = state * jnp.exp(lwt)[..., None] + jnp.einsum(
            "bhk,bhv->bhkv", kt, vt
        )
    else:
        o, new_state = wkv_chunked(r, k, v, logw, u, state)

    o = o.reshape(b, t, d)
    # per-head group norm == layer_norm over each head's slice
    o = o.reshape(b, t, h, hs)
    mu = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    o = (o - mu) * jax.lax.rsqrt(var + 64e-5)
    o = o.reshape(b, t, d) * p["gn_w"] + p["gn_b"]
    o = o.astype(x.dtype) * jax.nn.silu(g).reshape(b, t, d)
    out = jnp.einsum("bthk,hkd->btd", o.reshape(b, t, h, hs), p["wo"])
    return lshard(out, "batch", "seq", "embed"), xf[:, -1], new_state


def _channel_mix(p, cfg, x, last_x):
    xf = x.astype(jnp.float32)
    prev = _token_shift(x, last_x.astype(x.dtype))
    xx = prev - x
    xk = x + xx * p["mix_k"].astype(x.dtype)
    xr = x + xx * p["mix_r"].astype(x.dtype)
    k = jnp.einsum("btd,df->btf", xk, p["wk"])
    k = lshard(k, "batch", "seq", "mlp")
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("btf,fd->btd", k, p["wv"])
    out = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"])) * kv
    return lshard(out, "batch", "seq", "embed"), xf[:, -1]


def _layer_apply(p, cfg, x, st, mode):
    h, tm_last, wkv = _time_mix(
        p["time_mix"], cfg, layer_norm(x, p["ln1"]["w"], p["ln1"]["b"]),
        st["tm_last"], st["wkv"], mode,
    )
    x = x + h
    h2, cm_last = _channel_mix(
        p["channel_mix"], cfg, layer_norm(x, p["ln2"]["w"], p["ln2"]["b"]),
        st["cm_last"],
    )
    x = x + h2
    return x, {"tm_last": tm_last, "cm_last": cm_last, "wkv": wkv}


def init_rwkv_state(cfg: ArchConfig, batch: int) -> dict:
    h, hs, d = cfg.n_heads, cfg.rwkv.head_size, cfg.d_model
    ell = cfg.n_layers
    return {
        "tm_last": jnp.zeros((ell, batch, d), jnp.float32),
        "cm_last": jnp.zeros((ell, batch, d), jnp.float32),
        "wkv": jnp.zeros((ell, batch, h, hs, hs), jnp.float32),
    }


def _stack_forward(params, cfg, x, state, mode):
    def step(xc, xs):
        lp, st = xs
        xx, new_st = _layer_apply(lp, cfg, xc, st, mode)
        return xx, new_st

    if cfg.remat != "none":
        step = jax.checkpoint(step)
    x, new_state = jax.lax.scan(step, x, (params["layers"], state))
    return x, new_state


def _embed(params, cfg, tokens):
    e = jnp.take(params["embed"], tokens, axis=0)
    e = lshard(e, "batch", "seq", "embed")
    return layer_norm(e, params["ln_in"]["w"], params["ln_in"]["b"])


def _head(params, cfg, x):
    x = layer_norm(x, params["final_norm"]["w"], params["final_norm"]["b"])
    logits = jnp.einsum("btd,dv->btv", x.astype(jnp.bfloat16),
                        params["unembed"].astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    return lshard(logits, "batch", "seq", "vocab")


def rwkv_loss(params, cfg, batch):
    from repro.models.losses import sharded_xent_loss

    x = _embed(params, cfg, batch["tokens"])
    state = init_rwkv_state(cfg, x.shape[0])
    x, _ = _stack_forward(params, cfg, x, state, "train")
    x = layer_norm(x, params["final_norm"]["w"], params["final_norm"]["b"])
    loss_sum, count = sharded_xent_loss(
        x, params["unembed"], batch["labels"], mask=batch.get("mask")
    )
    loss = loss_sum / jnp.maximum(count, 1.0)
    return loss, {"xent": loss}


def rwkv_prefill(params, cfg, batch, state):
    x = _embed(params, cfg, batch["tokens"])
    x, new_state = _stack_forward(params, cfg, x, state, "prefill")
    logits = _head(params, cfg, x[:, -1:])
    return logits, new_state


def rwkv_decode_step(params, cfg, state, batch, step):
    del step  # recurrent state is position-free
    x = _embed(params, cfg, batch["tokens"])
    x, new_state = _stack_forward(params, cfg, x, state, "decode")
    logits = _head(params, cfg, x)
    return logits, new_state
