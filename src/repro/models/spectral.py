"""Spectral long-convolution token mixer -- the coded-FFT model integration.

An FFT-based global-convolution layer (FNO/Hyena-style): each channel owns
a causal long filter h_d; mixing is ``y[:, :, d] = (x[:, :, d] * h_d)[:S]``
computed as ``irfft(rfft(pad(x)) . rfft(pad(h)))``.  This is the one place
in the LM zoo whose hot loop IS a Fourier transform, so it is where the
paper's technique applies to the assigned architectures: with
``use_coded=True`` the sequence-axis FFT runs through the coded plan
(``CodedFFT`` / ``DistributedCodedFFT``), inheriting straggler tolerance
for free by the linearity argument of §III-B.

The mixer is insertable in the SSM/hybrid families (DESIGN.md §4); at
500k+ context the O(S log S) conv replaces the O(S·W) window scan.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.core.coded_fft import CodedFFT
from repro.distributed.sharding import lshard
from repro.models.params import Spec

__all__ = ["spectral_specs", "spectral_apply", "spectral_apply_coded",
           "decaying_filter_init"]


def spectral_specs(d_model: int, filter_len: int, dtype=jnp.float32) -> dict:
    """Per-channel causal filters (d_model, filter_len) + skip gain."""
    return {
        "h": Spec((d_model, filter_len), ("p_fsdp", None), init="zeros", dtype=dtype),
        "decay": Spec((d_model,), ("p_fsdp",), init="zeros", dtype=dtype),
        "skip": Spec((d_model,), ("p_fsdp",), init="ones", dtype=dtype),
    }


def decaying_filter_init(key: jax.Array, d_model: int, filter_len: int,
                         dtype=jnp.float32) -> dict:
    """Sensible materialized init: smooth exponentially-decaying filters."""
    k1, k2 = jax.random.split(key)
    t = jnp.arange(filter_len, dtype=jnp.float32)
    rates = jax.random.uniform(k1, (d_model, 1), minval=0.001, maxval=0.1)
    base = jnp.exp(-rates * t) * jax.random.normal(k2, (d_model, filter_len)) * 0.02
    return {
        "h": base.astype(dtype),
        "decay": jnp.zeros((d_model,), dtype),
        "skip": jnp.ones((d_model,), dtype),
    }


def _effective_filter(p: dict, filter_len: int) -> jax.Array:
    """Learned filter modulated by a learned per-channel decay envelope."""
    t = jnp.arange(filter_len, dtype=jnp.float32)
    env = jnp.exp(-jax.nn.softplus(p["decay"])[:, None] * t[None, :])
    return p["h"].astype(jnp.float32) * env


def spectral_apply(p: dict, x: jax.Array, *,
                   fft_fn=None) -> jax.Array:
    """Causal FFT long-conv.  x: (B, S, D) -> (B, S, D).

    ``fft_fn``: optional replacement for the sequence FFT pair -- signature
    ``fft_fn(x_complex) -> X`` operating along the last axis (the coded
    plan's worker path plugs in here).
    """
    b, s, d = x.shape
    h = _effective_filter(p, p["h"].shape[-1])          # (D, F)
    f = h.shape[-1]
    n = 1
    while n < s + f:                                     # linear (causal) conv
        n *= 2
    xf = jnp.fft.rfft(x.astype(jnp.float32), n=n, axis=1)        # (B, n/2+1, D)
    hf = jnp.fft.rfft(h, n=n, axis=-1).T[None]                   # (1, n/2+1, D)
    y = jnp.fft.irfft(xf * hf, n=n, axis=1)[:, :s]
    y = y + x.astype(jnp.float32) * p["skip"].astype(jnp.float32)
    return lshard(y.astype(x.dtype), "batch", "seq", "embed")


def spectral_apply_coded(p: dict, x: jax.Array, plan: CodedFFT,
                         mask: Optional[jax.Array] = None) -> jax.Array:
    """Same mixer, but the forward sequence FFT runs under the coded plan.

    The conv theorem needs a full complex FFT of length ``plan.s``; each
    (batch, channel) row is one transform request.  Demonstrates Theorem 5
    territory (many inputs) at model scale; small shapes only on CPU.
    """
    b, s, d = x.shape
    h = _effective_filter(p, p["h"].shape[-1])
    n = plan.s
    assert n >= s + h.shape[-1], "plan.s must cover linear conv length"

    rows = jnp.moveaxis(x.astype(jnp.complex64), 1, -1).reshape(b * d, s)
    rows = jnp.pad(rows, ((0, 0), (0, n - s)))
    xf = jax.vmap(lambda r: plan.run(r, mask=mask))(rows)        # coded FFT
    xf = xf.reshape(b, d, n)
    hf = jnp.fft.fft(h, n=n, axis=-1)[None]                      # (1, D, n)
    y = jnp.fft.ifft(xf * hf, axis=-1).real[..., :s]             # (B, D, S)
    y = jnp.moveaxis(y, 1, -1)
    y = y + x.astype(jnp.float32) * p["skip"].astype(jnp.float32)
    return y.astype(x.dtype)
