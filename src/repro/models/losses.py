"""Vocab-sharded, sequence-chunked cross-entropy.

Full logits of shape (B, S, V) are never materialized unsharded: the
unembedding runs per sequence-chunk inside a ``lax.scan``, logits stay
sharded over the vocab ('model') axis, the label logit is extracted with an
iota==label mask (which partitions cleanly -- no gather across vocab
shards), and logsumexp reduces over the sharded axis (GSPMD inserts the
psum).  This is what makes 256k-vocab train cells fit per-device HBM.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import lshard

__all__ = ["sharded_xent_loss"]


def sharded_xent_loss(
    hidden: jax.Array,          # (B, S, D)
    unembed: jax.Array,         # (D, V)
    labels: jax.Array,          # (B, S) int32
    *,
    mask: Optional[jax.Array] = None,   # (B, S) {0,1}
    logit_divisor: float = 1.0,
    seq_chunk: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """Returns (sum_of_token_losses, token_count) -- caller divides."""
    b, s, d = hidden.shape
    v = unembed.shape[-1]
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    seq_chunk = min(seq_chunk, s)
    if s % seq_chunk != 0:
        pad = seq_chunk - s % seq_chunk
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        s += pad
    n_chunks = s // seq_chunk

    hs = jnp.moveaxis(hidden.reshape(b, n_chunks, seq_chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, n_chunks, seq_chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(b, n_chunks, seq_chunk), 1, 0)

    def step(carry, xs):
        loss_sum, count = carry
        h, lab, msk = xs
        logits = jnp.einsum(
            "bcd,dv->bcv", h.astype(jnp.bfloat16), unembed.astype(jnp.bfloat16),
            preferred_element_type=jnp.float32,
        )
        logits = logits / logit_divisor
        logits = lshard(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)                     # psum over vocab shards
        viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        label_logit = jnp.sum(
            jnp.where(viota == lab[..., None], logits, 0.0), axis=-1
        )                                                            # psum over vocab shards
        token_loss = (lse - label_logit) * msk
        return (loss_sum + token_loss.sum(), count + msk.sum()), None

    (loss_sum, count), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hs, ls, ms)
    )
    return loss_sum, count
