"""Coded FFT inside a model: a spectral long-conv mixer whose sequence FFT
runs under the paper's coded computation plan.

The mixer computes y = irfft(rfft(x) * rfft(h)) per channel; because the
DFT is linear, running it through the (N, m)-MDS coded plan gives the
layer straggler tolerance for free (paper §III-B linearity argument).  We
knock out N - m workers mid-"training" and show the layer's output -- and
its gradients -- are unchanged.

Run:  PYTHONPATH=src python examples/coded_spectral_lm.py
"""

import jax
import jax.numpy as jnp

from repro.core import CodedFFT
from repro.models.spectral import (
    decaying_filter_init,
    spectral_apply,
    spectral_apply_coded,
)


def main() -> None:
    key = jax.random.PRNGKey(0)
    d_model, seq, filt = 32, 96, 32
    p = decaying_filter_init(key, d_model, filt)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, seq, d_model))

    # plain spectral mixer (what an FNO/Hyena-style block computes)
    y_plain = spectral_apply(p, x)

    # the same mixer, FFT executed via the coded plan with 2/6 workers dead
    plan = CodedFFT(s=256, m=4, n_workers=6)
    mask = jnp.asarray([True, False, True, True, False, True])
    y_coded = spectral_apply_coded(p, x, plan, mask=mask)

    err = float(jnp.max(jnp.abs(y_plain - y_coded)))
    print(f"[spectral] coded vs plain mixer output err: {err:.2e} "
          f"(2/{plan.n_workers} workers down)")
    assert err < 1e-3

    # gradients flow through the coded path identically
    loss_plain = lambda pp: (spectral_apply(pp, x) ** 2).mean()
    loss_coded = lambda pp: (spectral_apply_coded(pp, x, plan, mask=mask) ** 2).mean()
    g1 = jax.grad(loss_plain)(p)["h"]
    g2 = jax.grad(loss_coded)(p)["h"]
    gerr = float(jnp.max(jnp.abs(g1 - g2)))
    print(f"[spectral] filter-gradient err coded vs plain: {gerr:.2e}")
    assert gerr < 1e-4
    print("[spectral] straggler-tolerant spectral layer: OK")


if __name__ == "__main__":
    main()
