"""Coded FFT quickstart -- the paper's construction, then the service.

Part 1 walks the four plan stages by hand (encode -> worker -> straggle
-> decode); part 2 serves an n-D REAL transform through the batched
FFTService front end (half-payload worker shards, DESIGN.md §9).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CodedFFT, coded_fft_threshold, repetition_threshold
from repro.serving import FFTService, FFTServiceConfig

# Problem: compute X = F{x} for a length-4096 vector on N=8 workers that
# can each hold 1/4 of the input (m=4).  Theorem 1: any 4 workers suffice.
s, m, n_workers = 4096, 4, 8
plan = CodedFFT(s=s, m=m, n_workers=n_workers)
print(f"recovery threshold: coded={plan.recovery_threshold} "
      f"(repetition would need {repetition_threshold(16, m)} of 16)")

key = jax.random.PRNGKey(0)
x = (jax.random.normal(key, (s,)) + 1j * jax.random.normal(key, (s,))
     ).astype(jnp.complex64)

# 1. master encodes: interleave into m shards, apply the (N, m) complex
#    Reed-Solomon code -> one coded shard per worker
a = plan.encode(x)                      # (8, 1024)

# 2. workers each FFT their own shard (linearity => results stay RS-coded)
b = plan.worker_compute(a)              # (8, 1024)

# 3. four workers straggle -- TWO MORE than uncoded could ever lose.
#    Their rows are garbage; the master never reads them.
b = b.at[jnp.asarray([0, 3, 5, 6])].set(jnp.nan)
mask = jnp.asarray([False, True, True, False, True, False, False, True])

# 4. master decodes from the fastest m=4 workers + recombines (Cooley-Tukey)
X = plan.decode(b, mask=mask)

err = float(jnp.max(jnp.abs(X - jnp.fft.fft(x))))
print(f"max |coded FFT - jnp.fft.fft| with 4/8 workers down: {err:.2e}")
assert err < 1e-2, "decode failed"
print("straggler-tolerant FFT: OK")

# ---- part 2: the service front end, serving an n-D REAL transform ----------
# The batched FFTService buckets requests by (s, m, kind), simulates
# per-request stragglers, and answers from the fastest m workers.  Real
# kinds (r2c/c2r/rfftn/irfftn) pair-pack their shards, so each worker
# ships HALF the payload of the complex plan -- here a 2-D rfftn request.
svc = FFTService(FFTServiceConfig(s=s, m=m, n_workers=n_workers))
t = np.random.default_rng(0).normal(size=(64, 64)).astype(np.float32)
T = svc.submit_rfftn(jnp.asarray(t))    # == numpy.fft.rfftn(t), (64, 33)
err = float(np.abs(T - np.fft.rfftn(t.astype(np.float64))).max())
print(f"service rfftn (64, 64) -> {T.shape}: max err vs numpy {err:.2e}")
assert err < 1e-2, "rfftn service decode failed"
st = svc.stats.summary()
print(f"coded latency {st['mean_coded_latency']:.3f}s vs uncoded "
      f"{st['mean_uncoded_latency']:.3f}s "
      f"({st['stragglers_tolerated']} stragglers tolerated)")
print("n-D real coded FFT service: OK")
