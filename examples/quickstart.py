"""Coded FFT quickstart -- the paper's construction in 40 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import CodedFFT, coded_fft_threshold, repetition_threshold

# Problem: compute X = F{x} for a length-4096 vector on N=8 workers that
# can each hold 1/4 of the input (m=4).  Theorem 1: any 4 workers suffice.
s, m, n_workers = 4096, 4, 8
plan = CodedFFT(s=s, m=m, n_workers=n_workers)
print(f"recovery threshold: coded={plan.recovery_threshold} "
      f"(repetition would need {repetition_threshold(16, m)} of 16)")

key = jax.random.PRNGKey(0)
x = (jax.random.normal(key, (s,)) + 1j * jax.random.normal(key, (s,))
     ).astype(jnp.complex64)

# 1. master encodes: interleave into m shards, apply the (N, m) complex
#    Reed-Solomon code -> one coded shard per worker
a = plan.encode(x)                      # (8, 1024)

# 2. workers each FFT their own shard (linearity => results stay RS-coded)
b = plan.worker_compute(a)              # (8, 1024)

# 3. four workers straggle -- TWO MORE than uncoded could ever lose.
#    Their rows are garbage; the master never reads them.
b = b.at[jnp.asarray([0, 3, 5, 6])].set(jnp.nan)
mask = jnp.asarray([False, True, True, False, True, False, False, True])

# 4. master decodes from the fastest m=4 workers + recombines (Cooley-Tukey)
X = plan.decode(b, mask=mask)

err = float(jnp.max(jnp.abs(X - jnp.fft.fft(x))))
print(f"max |coded FFT - jnp.fft.fft| with 4/8 workers down: {err:.2e}")
assert err < 1e-2, "decode failed"
print("straggler-tolerant FFT: OK")
