"""End-to-end LM training driver (deliverable b): data pipeline -> model ->
AdamW(WSD) -> fault-tolerant trainer with async checkpoints.

Default is a CPU-friendly ~15M-param MiniCPM-family model for 60 steps;
``--params-100m --steps 300`` gives the full-size driver (same code path,
just slower on CPU).  Kill it mid-run and rerun: it resumes bit-exactly
from the last checkpoint.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps N] [--params-100m]
"""

import argparse
import dataclasses

from repro.configs import ShapeConfig, get_reduced_config
from repro.data import make_pipeline
from repro.models import build_model
from repro.optim import adamw, wsd
from repro.training import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--params-100m", action="store_true",
                    help="~100M-param config (slow on CPU; same code path)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_reduced_config("minicpm-2b")
    if args.params_100m:
        cfg = dataclasses.replace(
            cfg, name="minicpm-100m", n_layers=12, d_model=512, n_heads=8,
            n_kv_heads=8, head_dim=64, d_ff=2048, vocab_size=32768)
    else:
        cfg = dataclasses.replace(
            cfg, name="minicpm-15m", n_layers=6, d_model=256, n_heads=4,
            n_kv_heads=4, head_dim=64, d_ff=1024, vocab_size=8192)

    model = build_model(cfg)
    print(f"[example] {cfg.name}: {model.n_params / 1e6:.1f}M params")
    shape = ShapeConfig("example", seq_len=256, global_batch=8, kind="train")
    pipe = make_pipeline(cfg, shape)
    opt = adamw(wsd(3e-3, args.steps, max(args.steps // 10, 1)))
    trainer = Trainer(model, opt, pipe, TrainerConfig(
        total_steps=args.steps, checkpoint_every=20,
        checkpoint_dir=args.ckpt_dir, log_every=10, n_micro=2))
    _, metrics = trainer.run()
    print(f"[example] final loss {metrics['loss']:.4f} "
          f"(start was ~ln(vocab)={__import__('math').log(cfg.vocab_size):.2f})")


if __name__ == "__main__":
    main()
