"""The paper's own application end-to-end: a straggler-tolerant FFT service.

Submits a stream of transform requests; each request's workers draw
shifted-exponential latencies, the service answers after the fastest m,
and every answer is verified against jnp.fft.  With 8 local devices
(XLA_FLAGS=--xla_force_host_platform_device_count=8) the worker compute
runs under shard_map across a real device mesh; with 1 device it runs the
same math locally.

Run:  PYTHONPATH=src python examples/fft_service_demo.py
      XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
          PYTHONPATH=src python examples/fft_service_demo.py --mesh
"""

import argparse

import jax
import jax.numpy as jnp

from repro.distributed.straggler import StragglerModel
from repro.serving import FFTService, FFTServiceConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", action="store_true",
                    help="run workers under shard_map (needs >= 8 devices)")
    ap.add_argument("--requests", type=int, default=12)
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        from repro.distributed import test_mesh

        mesh = test_mesh((8,), ("workers",))
        print(f"[demo] shard_map over {jax.device_count()} devices")

    svc = FFTService(
        FFTServiceConfig(s=4096, m=4, n_workers=8,
                         straggler=StragglerModel(t0=1.0, mu=1.0)),
        mesh=mesh)

    # the batched scheduler: one jitted encode/decode per (s, m) bucket,
    # per-request straggler masks (DESIGN.md §5)
    key = jax.random.PRNGKey(0)
    xs = []
    for i in range(args.requests):
        key, k1, k2 = jax.random.split(key, 3)
        xs.append((jax.random.normal(k1, (4096,))
                   + 1j * jax.random.normal(k2, (4096,))).astype(jnp.complex64))
    for x, y in zip(xs, svc.submit_batch(xs)):
        err = float(jnp.max(jnp.abs(y - jnp.fft.fft(x))))
        assert err < 1e-2, err
    st = svc.stats.summary()
    print(f"[demo] {st['requests']} requests all correct "
          f"({st['batches']} scheduler batch(es))")
    print(f"[demo] mean latency: coded {st['mean_coded_latency']:.3f}s, "
          f"wait-for-all {st['mean_uncoded_latency']:.3f}s "
          f"-> {st['speedup']:.2f}x faster")
    print(f"[demo] stragglers tolerated (worker-requests never waited on): "
          f"{st['stragglers_tolerated']}")


if __name__ == "__main__":
    main()
