"""CI smoke for the kernel autotuner (DESIGN.md §10): cold search ->
persisted table -> warm reuse, on a tiny candidate set.

Runs in the kernels-interpret job.  The point is structural, not perf:
a search actually executes the candidate variants, the winning entries
land in the backend-keyed JSON cache, a simulated fresh process reloads
that file instead of re-searching, and dispatch reads the recorded
winner.  Everything here is seconds-cheap (L=64, s=64 buckets, 1 rep).
"""

import json
import os
import sys
import tempfile


def main() -> int:
    tmp = tempfile.mkdtemp(prefix="autotune-smoke-")
    os.environ["REPRO_AUTOTUNE_CACHE"] = tmp

    from repro.kernels import autotune, ops

    # -- cold search: one four-step shape, one bucket shape, tiny reps
    before = autotune.searches_run()
    fent = autotune.ensure_fourstep(64, batch=2, mode="direct", reps=1)
    bent = autotune.ensure_bucket("bucket", 64, 2, 4, q=4, mode="direct",
                                  reps=1)
    assert autotune.searches_run() == before + 2, "searches did not run"
    assert fent["variant"] in ("fused", "two_pass", "xla"), fent
    assert bent["block_q"] in (1, 2, 4), bent

    # -- the table was persisted, backend-keyed, schema-stamped
    path = autotune.cache_path()
    assert path.exists(), f"no cache file at {path}"
    blob = json.loads(path.read_text())
    assert blob["version"] == autotune.SCHEMA_VERSION
    keys = sorted(blob["entries"])
    assert any(k.startswith("fourstep|") for k in keys), keys
    assert any(k.startswith("bucket|") for k in keys), keys

    # -- warm reuse: a fresh process (memory dropped, disk kept) must do
    #    ZERO additional searches for the same keys
    autotune.clear(memory_only=True)
    warm_f = autotune.ensure_fourstep(64, batch=2, mode="direct", reps=1)
    warm_b = autotune.ensure_bucket("bucket", 64, 2, 4, q=4, mode="direct",
                                    reps=1)
    assert autotune.searches_run() == before + 2, "warm path re-searched"
    assert warm_f["variant"] == fent["variant"]
    assert warm_b["block_q"] == bent["block_q"]

    # -- dispatch reads the recorded winner
    got = ops._tuned_block_q("bucket", 4, 10**9, "direct", s=64, m=2, n=4)
    assert got == bent["block_q"], (got, bent)

    print(f"autotune smoke ok: {len(keys)} entries in {path.name}, "
          f"fourstep->{fent['variant']}, bucket block_q={bent['block_q']}, "
          f"warm reuse verified")
    return 0


if __name__ == "__main__":
    sys.exit(main())
