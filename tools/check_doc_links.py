"""Docs dead-link check (CI `docs-links` job; stdlib only).

Three classes of reference are verified against the working tree:

1. markdown links `[text](target)` in README.md / DESIGN.md whose target
   is a local path (http(s) links are skipped -- CI must not flake on
   third-party outages);
2. backticked repo paths like `src/repro/core/rfftn.py`,
   `tests/test_rfftn.py`, or `benchmarks/bench_service.py` -- the docs
   lean on these heavily as the architecture map;
3. DESIGN.md section anchors: every `§k` the README cites must exist as
   a `## §k` heading in DESIGN.md.

Exit code 1 with a per-reference report on any miss.
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = ["README.md", "DESIGN.md"]
# docs reference library files relative to the repo root OR to src/repro
# (`core/mds.py`, `kernels/coded_pipeline.py`, ...)
BASES = [ROOT, ROOT / "src", ROOT / "src" / "repro"]
# backticked tokens that are file paths: a slash plus a real extension
# (math like `L/2`, dotted attrs like `mod.fn`, and bare dirs are prose)
PATHLIKE = re.compile(
    r"`([A-Za-z0-9_.-]+(?:/[A-Za-z0-9_.-]+)+\.(?:py|md|json|yml|toml))`")
DIRLIKE = re.compile(r"`([A-Za-z0-9_-]+(?:/[A-Za-z0-9_-]+)*/)`")
MDLINK = re.compile(r"\[[^\]]+\]\(([^)#\s]+)[^)]*\)")
SECTION = re.compile(r"§(\d+)")


def exists(token: str) -> bool:
    return any((base / token).exists() for base in BASES)


def main() -> int:
    errors: list[str] = []
    design = (ROOT / "DESIGN.md").read_text()
    sections = {int(m) for m in SECTION.findall(
        "\n".join(line for line in design.splitlines()
                  if line.startswith("## ")))}

    for name in DOCS:
        text = (ROOT / name).read_text()
        for target in MDLINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if not (ROOT / target).exists():
                errors.append(f"{name}: markdown link -> missing {target!r}")
        for token in PATHLIKE.findall(text) + DIRLIKE.findall(text):
            if not exists(token):
                errors.append(f"{name}: path reference -> missing {token!r}")
        for num in {int(m) for m in SECTION.findall(text)}:
            if num not in sections:
                errors.append(
                    f"{name}: cites DESIGN.md §{num}, no such section")

    if errors:
        print("\n".join(errors))
        print(f"\n{len(errors)} dead reference(s).")
        return 1
    print(f"docs link check OK ({', '.join(DOCS)}; "
          f"{len(sections)} DESIGN sections)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
